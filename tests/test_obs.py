"""Observability layer: MetricsRegistry semantics, the disabled-by-default
no-op path, exporters (JSON snapshot + Prometheus textfile + validator),
and JobTracer lifecycle spans against the simulator.
"""

import pytest

from repro.core import QueueCache
from repro.core import events as ev
from repro.core.job import Job
from repro.core.resources import Opts
from repro.obs import metrics as m
from repro.obs.export import (
    load_snapshot,
    parse_textfile,
    prometheus_from_snapshot,
    session_stats,
    snapshot,
    to_prometheus,
    write_snapshot,
    write_textfile,
)
from repro.obs.trace import JobTracer


@pytest.fixture(autouse=True)
def _obs_disabled():
    """The active registry is module-global: leave every test clean."""
    m.disable()
    yield
    m.disable()


def make_job(name="j", *, cpus=1, time="1h", duration=60, hold=False):
    opts = Opts.new(threads=cpus, memory="1GB", time=time)
    opts.hold = hold
    return Job(name=name, command="true", opts=opts, sim_duration_s=duration)


class TestRegistry:
    def test_counter_inc(self):
        reg = m.MetricsRegistry()
        c = reg.counter("c_total", "help text")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5

    def test_labels_are_separate_children(self):
        reg = m.MetricsRegistry()
        c = reg.counter("c_total", labels=("cluster",))
        c.labels(cluster="a").inc()
        c.labels(cluster="a").inc()
        c.labels(cluster="b").inc()
        assert c.labels(cluster="a").value == 2
        assert c.labels(cluster="b").value == 1
        assert len(c.samples()) == 2

    def test_gauge_set_dec(self):
        reg = m.MetricsRegistry()
        g = reg.gauge("g")
        g.set(10)
        g.dec(3)
        assert g.value == 7.0

    def test_histogram_buckets(self):
        reg = m.MetricsRegistry()
        h = reg.histogram("h_seconds", buckets=(1.0, 10.0))
        for v in (0.5, 0.9, 5.0, 100.0):
            h.observe(v)
        child = h.samples()[0][1]
        assert child.counts == [2, 1, 1]  # ≤1, ≤10, +Inf overflow
        assert child.count == 4 and child.sum == pytest.approx(106.4)

    def test_declaration_is_idempotent(self):
        reg = m.MetricsRegistry()
        assert reg.counter("c_total") is reg.counter("c_total")

    def test_kind_mismatch_raises(self):
        reg = m.MetricsRegistry()
        reg.counter("c_total")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("c_total")

    def test_undeclared_label_raises(self):
        reg = m.MetricsRegistry()
        c = reg.counter("c_total", labels=("cluster",))
        with pytest.raises(ValueError, match="do not match declared"):
            c.labels(wrong="x")

    def test_labelless_call_on_labeled_family_raises(self):
        reg = m.MetricsRegistry()
        c = reg.counter("c_total", labels=("cluster",))
        with pytest.raises(ValueError, match="use .labels"):
            c.inc()

    def test_reset_drops_families(self):
        reg = m.MetricsRegistry()
        reg.counter("c_total").inc()
        reg.reset()
        assert reg.get("c_total") is None and reg.families() == []


class TestEnableDisable:
    def test_disabled_by_default(self):
        reg = m.get_registry()
        assert reg.enabled is False
        # recording into the null registry is a silent no-op
        reg.counter("x_total").inc()
        reg.histogram("h").observe(1.0)
        assert reg.families() == []

    def test_enable_swaps_in_real_registry(self):
        reg = m.enable()
        assert reg.enabled and m.get_registry() is reg
        reg.counter("x_total").inc()
        assert reg.get("x_total").value == 1

    def test_enable_is_idempotent(self):
        reg = m.enable()
        reg.counter("x_total").inc()
        assert m.enable() is reg  # counters survive a second enable()
        assert reg.get("x_total").value == 1

    def test_null_metrics_are_shared_singletons(self):
        null = m.NULL_REGISTRY
        assert null.counter("a") is null.histogram("b") is null.gauge("c")
        assert null.counter("a").labels(anything="x") is null.counter("a")

    def test_timed_null_histogram_is_free(self):
        assert m.timed(m.NULL_REGISTRY.histogram("h")) is m._NULL_TIMER

    def test_timed_records_elapsed(self):
        reg = m.MetricsRegistry()
        h = reg.histogram("h_seconds")
        with m.timed(h):
            pass
        child = h.samples()[0][1]
        assert child.count == 1 and child.sum >= 0.0


class TestExport:
    def _populated(self):
        reg = m.MetricsRegistry()
        reg.counter("nbi_a_total", "a counter", labels=("cluster",)) \
            .labels(cluster="green").inc(3)
        reg.gauge("nbi_b", "a gauge").set(7)
        h = reg.histogram("nbi_c_seconds", "a histogram", buckets=(1.0, 10.0))
        h.observe(0.5)
        h.observe(99.0)
        return reg

    def test_snapshot_shape(self):
        snap = snapshot(self._populated(), meta={"k": "v"})
        assert snap["meta"] == {"k": "v"}
        fam = snap["metrics"]["nbi_a_total"]
        assert fam["type"] == "counter" and fam["help"] == "a counter"
        assert fam["series"] == [
            {"labels": {"cluster": "green"}, "value": 3.0}
        ]
        hist = snap["metrics"]["nbi_c_seconds"]["series"][0]
        # cumulative buckets, ending with the +Inf total == count
        assert hist["buckets"] == [[1.0, 1], [10.0, 1], ["+Inf", 2]]
        assert hist["count"] == 2

    def test_prometheus_roundtrip(self):
        text = to_prometheus(self._populated())
        assert '# TYPE nbi_a_total counter' in text
        assert 'nbi_a_total{cluster="green"} 3' in text
        assert 'nbi_c_seconds_bucket{le="+Inf"} 2' in text
        families = parse_textfile(text)  # validator accepts the exporter
        assert families["nbi_c_seconds"]["type"] == "histogram"
        assert families["nbi_a_total"]["samples"] == 1

    def test_write_and_load_snapshot(self, tmp_path):
        path = tmp_path / "snap.json"
        write_snapshot(path, self._populated(), meta={"jobs": 2})
        snap = load_snapshot(path)
        assert snap["meta"]["jobs"] == 2
        # a snapshot file renders to the same exposition as the registry
        assert prometheus_from_snapshot(snap) == to_prometheus(self._populated())

    def test_write_textfile(self, tmp_path):
        path = tmp_path / "out.prom"
        text = write_textfile(path, self._populated())
        assert path.read_text() == text
        parse_textfile(text)

    def test_label_escaping_roundtrips(self):
        reg = m.MetricsRegistry()
        reg.counter("nbi_esc_total", labels=("name",)) \
            .labels(name='we"ird\\name').inc()
        parse_textfile(to_prometheus(reg))

    @pytest.mark.parametrize("bad", [
        'nbi_x{le=}"oops"} 1',            # malformed labels
        'nbi_x 1 2 3',                    # multi-token value
        'nbi_x notanumber',               # unparseable value
        'nbi_x NaN',                      # NaN sample
        '# TYPE nbi_x wat',               # unknown TYPE
    ])
    def test_parse_rejects_malformed(self, bad):
        with pytest.raises(ValueError):
            parse_textfile(bad + "\n")

    def test_parse_rejects_non_cumulative_histogram(self):
        text = (
            "# TYPE nbi_h histogram\n"
            'nbi_h_bucket{le="1"} 5\n'
            'nbi_h_bucket{le="10"} 3\n'  # decreasing — not cumulative
            'nbi_h_bucket{le="+Inf"} 5\n'
            "nbi_h_count 5\n"
        )
        with pytest.raises(ValueError, match="not cumulative"):
            parse_textfile(text)

    def test_parse_rejects_count_inf_disagreement(self):
        text = (
            "# TYPE nbi_h histogram\n"
            'nbi_h_bucket{le="+Inf"} 5\n'
            "nbi_h_count 4\n"
        )
        with pytest.raises(ValueError, match="_count"):
            parse_textfile(text)

    def test_parse_rejects_missing_inf(self):
        text = (
            "# TYPE nbi_h histogram\n"
            'nbi_h_bucket{le="1"} 5\n'
        )
        with pytest.raises(ValueError, match=r"\+Inf"):
            parse_textfile(text)


class TestJobTracer:
    def test_full_lifecycle_spans(self, sim):
        reg = m.enable()
        tracer = JobTracer().attach(sim.bus)
        jids = [str(make_job(name=f"j{i}", duration=60 * (i + 1)).run(sim))
                for i in range(3)]
        sim.advance(3600)
        tracer.detach()

        assert tracer.finished == 3 and not tracer.open
        assert tracer.outcomes == {"COMPLETED": 3}
        span = next(s for s in tracer.recent if s.jobid == jids[0])
        assert [t for t, _ in span.timeline] == [
            ev.SUBMITTED, ev.STARTED, ev.COMPLETED,
        ]
        assert span.queue_wait_s is not None and span.queue_wait_s >= 0
        assert span.lifetime_s == pytest.approx(60, abs=1)
        assert span.hold_s is None  # never held
        # the registry saw the same story
        assert reg.get("nbi_trace_spans_total") \
            .labels(outcome=ev.COMPLETED).value == 3
        assert reg.get("nbi_trace_open_spans").value == 0
        assert reg.get("nbi_trace_lifetime_seconds") \
            .labels(cluster="").count == 3

    def test_held_job_records_hold_duration(self, sim):
        m.enable()
        tracer = JobTracer().attach(sim.bus)
        jid = str(make_job(hold=True, duration=60).run(sim))
        sim.advance(300)
        assert tracer.open[jid].held  # observed PENDING (JobHeldUser)
        sim.release([jid])
        sim.advance(3600)
        tracer.detach()
        span = next(s for s in tracer.recent if s.jobid == jid)
        assert span.held and span.outcome == ev.COMPLETED
        assert span.hold_s == pytest.approx(300, abs=1)

    def test_timeout_outcome(self, sim):
        tracer = JobTracer().attach(sim.bus)
        jid = str(make_job(time="1m", duration=3600).run(sim))
        sim.advance(7200)
        tracer.detach()
        span = next(s for s in tracer.recent if s.jobid == jid)
        assert span.outcome == ev.TIMEOUT
        assert tracer.outcomes == {ev.TIMEOUT: 1}

    def test_exact_tallies_survive_disabled_metrics(self, sim):
        # no enable(): null registry, but the plain-int accounting is exact
        tracer = JobTracer().attach(sim.bus)
        for i in range(5):
            make_job(name=f"j{i}", duration=60).run(sim)
        sim.advance(3600)
        tracer.detach()
        assert tracer.seen > 0
        assert tracer.finished == 5 and tracer.to_dict()["spans_open"] == 0

    def test_recent_is_bounded_but_counts_exact(self, sim):
        tracer = JobTracer(keep=2).attach(sim.bus)
        for i in range(5):
            make_job(name=f"j{i}", duration=60).run(sim)
        sim.advance(3600)
        tracer.detach()
        assert len(tracer.recent) == 2 and tracer.finished == 5

    def test_detach_stops_folding(self, sim):
        tracer = JobTracer().attach(sim.bus)
        make_job(duration=60).run(sim)
        tracer.detach()
        sim.advance(3600)
        assert tracer.finished == 0  # terminal event arrived after detach


class TestSessionStats:
    def test_queue_cache_headlines(self, sim):
        cache = QueueCache(sim, ttl_s=60.0)
        cache.queue()
        cache.queue()
        stats = session_stats(cache=cache)
        qc = stats["queue_cache"]
        assert qc["polls"] == 1 and qc["hits"] == 1
        assert qc["polls_saved"] == 1 and qc["hit_rate"] == 0.5
        assert "registry" not in stats  # metrics disabled

    def test_registry_included_when_enabled(self, sim):
        reg = m.enable()
        reg.counter("nbi_x_total").inc()
        stats = session_stats(cache=QueueCache(sim))
        assert "nbi_x_total" in stats["registry"]

    def test_tracer_summary(self):
        stats = session_stats(tracer=JobTracer())
        assert stats["trace"]["spans_finished"] == 0
