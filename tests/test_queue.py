"""NBI::Queue / QueuedJob — querying and filtering (paper §Queue)."""

from repro.core import Job, Opts, Queue, QueuedJob


def submit(sim, name="j", user=None, queue="main", duration=60):
    job = Job(name=name, command="true",
              opts=Opts.new(queue=queue, threads=2, memory="1GB", time="1h"),
              sim_duration_s=duration)
    jid = job.run(sim)
    if user:
        sim.get(jid).user = user
    return jid


class TestQueuedJob:
    def test_from_squeue_line(self):
        line = "123|alice|main|align|RUNNING|0-00:10:00|0-00:50:00|0-01:00:00|n001||4|4096"
        j = QueuedJob.from_squeue_line(line)
        assert j.jobid == "123" and j.user == "alice" and j.state == "RUNNING"
        assert j.jobid_num == 123
        assert j.is_active()

    def test_malformed_line(self):
        assert QueuedJob.from_squeue_line("garbage") is None

    def test_array_task_id(self):
        j = QueuedJob(jobid="123_4")
        assert j.jobid_num == 123


class TestQueueFiltering:
    def test_filter_by_user(self, sim):
        submit(sim, "a", user="alice")
        submit(sim, "b", user="bob")
        q = Queue(user="alice", backend=sim)
        assert len(q) == 1 and q.jobs[0].user == "alice"

    def test_filter_by_state(self, sim):
        # 2 nodes × 64 cpus; 2-cpu jobs: all run. Make 1 pending via resources
        for i in range(3):
            submit(sim, f"j{i}")
        q_running = Queue(state="RUNNING", backend=sim)
        assert all(j.state == "RUNNING" for j in q_running)

    def test_filter_by_name_regex(self, sim):
        submit(sim, "align-1")
        submit(sim, "align-2")
        submit(sim, "assembly")
        q = Queue(name=r"^align-\d$", backend=sim)
        assert len(q) == 2

    def test_filter_by_partition(self, sim):
        submit(sim, "a", queue="fast")
        submit(sim, "b", queue="slow")
        q = Queue(queue="fast", backend=sim)
        assert len(q) == 1 and q.jobs[0].queue == "fast"

    def test_terminal_jobs_absent(self, sim):
        submit(sim, "done", duration=10)
        sim.run_until_idle()
        assert len(Queue(backend=sim)) == 0

    def test_ids_and_by_user(self, sim):
        submit(sim, "a", user="alice")
        submit(sim, "b", user="bob")
        q = Queue(backend=sim)
        assert len(q.ids()) == 2
        assert set(q.by_user()) == {"alice", "bob"}

    def test_cancel_filtered(self, sim):
        submit(sim, "a", user="alice")
        submit(sim, "b", user="bob")
        q = Queue(user="bob", backend=sim)
        n = q.cancel()
        assert n == 1
        sim_states = {j.name: j.state for j in sim.accounting()}
        assert sim_states["b"] == "CANCELLED"
        assert sim_states["a"] != "CANCELLED"
