"""SubmitEngine / QueueCache — batch submission at scale (tentpole PR).

Covers the acceptance surface: array coalescing round-trips through the
simulator (each task runs *its own* command), QueueCache TTL/invalidation
semantics, and ``decide_many`` equivalence with per-job EcoScheduler calls.
"""

from datetime import datetime

import pytest

from repro.core import (
    CarbonTrace,
    EcoScheduler,
    Job,
    Opts,
    Queue,
    QueueCache,
    SimCluster,
    SubmitEngine,
    get_queue_cache,
)
from repro.core.config import NBIConfig


def homogeneous(n, command="true", **opt_kw):
    kw = dict(threads=1, memory="1GB", time="1h")
    kw.update(opt_kw)
    return [
        Job(name=f"j{i}", command=command.replace("{i}", str(i)),
            opts=Opts.new(**kw), sim_duration_s=30)
        for i in range(n)
    ]


class TestCoalescing:
    def test_homogeneous_jobs_fold_into_one_array(self, sim):
        result = SubmitEngine(sim).submit_many(homogeneous(5))
        assert result.sbatch_calls == 1
        assert result.coalesced == 5
        assert result.ids == [f"{result.base_ids[0]}_{k}" for k in range(5)]
        q = Queue(backend=sim)
        assert len(q) == 5
        assert q.base_ids() == result.base_ids
        assert sorted(j.array_task for j in q) == list(range(5))

    def test_array_tasks_run_their_own_command(self, exec_sim, tmp_path):
        jobs = homogeneous(4, command=f"echo {{i}} > {tmp_path}/out_{{i}}")
        result = SubmitEngine(exec_sim).submit_many(jobs)
        assert result.sbatch_calls == 1
        exec_sim.run_until_idle()
        for i in range(4):
            assert (tmp_path / f"out_{i}").read_text().strip() == str(i)
        engine = SubmitEngine(exec_sim)
        assert set(engine.states(result).values()) == {"COMPLETED"}

    def test_heterogeneous_resources_not_coalesced(self, sim):
        jobs = homogeneous(2) + homogeneous(2, threads=8)
        result = SubmitEngine(sim).submit_many(jobs)
        assert result.sbatch_calls == 2
        assert result.coalesced == 4

    def test_singletons_submitted_individually(self, sim):
        jobs = homogeneous(1) + homogeneous(1, threads=8)
        result = SubmitEngine(sim).submit_many(jobs)
        assert result.sbatch_calls == 2
        assert result.coalesced == 0
        assert all("_" not in jid for jid in result.ids)

    def test_multi_command_and_file_array_jobs_excluded(self, sim):
        multi = Job(name="m", command=["a", "b"], opts=Opts.new())
        files = Job(name="f", command="x #FILE#", opts=Opts.new(),
                    files=["1", "2"])
        result = SubmitEngine(sim).submit_many(homogeneous(3) + [multi, files])
        assert result.coalesced == 3
        assert result.sbatch_calls == 3  # 1 array + 2 individual

    def test_coalesce_off_preserves_per_job_submissions(self, sim):
        result = SubmitEngine(sim, coalesce=False).submit_many(homogeneous(4))
        assert result.sbatch_calls == 4
        assert result.coalesced == 0

    def test_ids_map_back_to_input_jobs(self, sim):
        jobs = homogeneous(3)
        result = SubmitEngine(sim).submit_many(jobs)
        base = result.base_ids[0]
        assert [j.jobid for j in jobs] == [base] * 3
        assert all(j.script_path for j in jobs)

    def test_eco_batch_prices_once_and_defers(self, sim):
        now = datetime(2026, 7, 28, 14, 0)  # Tuesday afternoon
        sched = EcoScheduler(NBIConfig())
        engine = SubmitEngine(sim, eco=True, scheduler=sched, now=now)
        result = engine.submit_many(homogeneous(4))
        assert result.eco_deferred == 1  # one coalesced unit, one directive
        expected = sched.next_window(3600, now).begin_directive
        job = sim.get(result.base_ids[0])
        assert job.begin == datetime.fromisoformat(expected)


class TestSubmitMany:
    def test_backend_submit_many_used_and_order_preserved(self):
        class FakeBackend:
            def __init__(self):
                self.batches = []
                self._next = 100

            def submit(self, job):  # pragma: no cover - bypassed
                raise AssertionError("submit_many should be preferred")

            def submit_many(self, jobs):
                self.batches.append(list(jobs))
                ids = list(range(self._next, self._next + len(jobs)))
                self._next += len(jobs)
                return ids

            def queue(self):
                return []

        be = FakeBackend()
        jobs = homogeneous(2, threads=1) + homogeneous(2, threads=4)
        result = SubmitEngine(be).submit_many(jobs)
        assert len(be.batches) == 1 and len(be.batches[0]) == 2
        assert result.base_ids == [100, 101]

    def test_sim_submit_many_matches_sequential_schedule(self):
        a, b = SimCluster(), SimCluster()
        for job in homogeneous(6, threads=2):
            job.prepare()
            a.submit(job)
        b.submit_many([j.prepare() for j in homogeneous(6, threads=2)])
        sa = sorted((j.jobid, j.state, j.node) for j in a.jobs.values())
        sb = sorted((j.jobid, j.state, j.node) for j in b.jobs.values())
        assert sa == sb


class TestStatesParsing:
    def test_compressed_pending_array_row(self, sim):
        # real SLURM reports a PENDING array as one '123_[spec]' row
        class FakeSlurmQueue:
            def queue(self):
                return [
                    {"jobid": "123_[0-2,5%2]", "state": "PENDING"},
                    {"jobid": "123_3", "state": "RUNNING"},
                ]

        from repro.core import BatchResult

        engine = SubmitEngine(FakeSlurmQueue())
        result = BatchResult(ids=["123_0", "123_2", "123_3", "123_4", "123_5"])
        states = engine.states(result)
        assert states["123_0"] == "PENDING"
        assert states["123_2"] == "PENDING"
        assert states["123_3"] == "RUNNING"
        assert states["123_4"] == "COMPLETED"  # not in spec → left the queue
        assert states["123_5"] == "PENDING"

    def test_array_name_collapses_to_common_stem(self, sim):
        jobs = homogeneous(4)  # named j0..j3
        result = SubmitEngine(sim).submit_many(jobs)
        assert result.sbatch_calls == 1
        assert {j.name for j in Queue(backend=sim)} == {"j"}


class TestBatchSubmitError:
    def test_partial_failure_reports_submitted_ids(self):
        from repro.core import BatchSubmitError, SlurmBackend

        class FlakyBackend(SlurmBackend):
            def __init__(self):
                self.n = 0

            def submit(self, job):
                if job.name == "bad":
                    raise RuntimeError("sbatch: QOSMaxSubmitJobPerUserLimit")
                self.n += 1
                return 500 + self.n

        jobs = homogeneous(3)
        jobs[1].name = "bad"
        with pytest.raises(BatchSubmitError) as exc:
            FlakyBackend().submit_many([j.prepare() for j in jobs])
        assert sorted(exc.value.ids.values()) == [501, 502]
        assert list(exc.value.errors) == [1]


class TestQueueCache:
    def fake_clock(self):
        t = [0.0]

        def clock():
            return t[0]

        return t, clock

    def test_ttl_serves_snapshot_then_expires(self, sim):
        t, clock = self.fake_clock()
        cache = QueueCache(sim, ttl_s=2.0, clock=clock)
        SubmitEngine(sim).submit_many(homogeneous(3))
        cache.queue(); cache.queue()
        assert (cache.polls, cache.hits) == (1, 1)
        t[0] += 1.9
        cache.queue()
        assert (cache.polls, cache.hits) == (1, 2)
        t[0] += 0.2  # past the TTL
        cache.queue()
        assert (cache.polls, cache.hits) == (2, 2)

    def test_submit_and_cancel_invalidate(self, sim):
        cache = QueueCache(sim, ttl_s=3600.0)
        assert cache.queue() == []
        jid = Job(name="a", command="true", opts=Opts.new(),
                  sim_duration_s=30).run(cache)
        assert len(cache.queue()) == 1  # fresh poll sees the new job
        cache.cancel([jid])
        assert cache.queue() == []

    def test_sim_mutators_invalidate_through_wrapper(self, sim):
        cache = QueueCache(sim, ttl_s=3600.0)
        Job(name="a", command="true", opts=Opts.new(),
            sim_duration_s=30).run(cache)
        assert len(cache.queue()) == 1
        cache.advance(60)  # job completes in simulated time
        assert cache.queue() == []

    def test_queue_object_through_cache(self, sim):
        cache = QueueCache(sim, ttl_s=3600.0)
        SubmitEngine(sim).submit_many(homogeneous(3))
        cache.invalidate()
        q1 = Queue(backend=cache)
        q2 = Queue(backend=cache)
        assert q1.ids() == q2.ids()
        assert cache.polls == 1 and cache.hits == 1

    def test_shared_cache_resolves_and_rewrap_is_identity(self, sim):
        shared = get_queue_cache(sim)
        assert shared.inner is sim
        assert get_queue_cache(shared) is shared

    def test_engine_invalidates_shared_cache_on_submit(self, sim):
        shared = get_queue_cache(sim, ttl_s=3600.0)
        assert shared.queue() == []  # snapshot taken
        SubmitEngine(sim).submit_many(homogeneous(2))
        # writer went straight to the backend, yet shared readers see it
        assert len(shared.queue()) == 2


class TestDecideMany:
    NOW = datetime(2026, 7, 28, 14, 0)  # Tuesday afternoon
    DURATIONS = [60, 600, 3600, 6 * 3600, 26 * 3600, 90000]

    def test_equivalent_to_per_job_decisions(self):
        sched = EcoScheduler(NBIConfig())
        batch = sched.decide_many(self.DURATIONS, self.NOW)
        singles = [sched.next_window(d, self.NOW) for d in self.DURATIONS]
        assert batch == singles

    def test_equivalent_with_carbon_trace(self):
        trace = CarbonTrace([100.0 + (h % 24) * 10 for h in range(168)])
        sched = EcoScheduler(NBIConfig(), carbon_trace=trace)
        batch = sched.decide_many(self.DURATIONS, self.NOW)
        singles = [sched.next_window(d, self.NOW) for d in self.DURATIONS]
        assert batch == singles

    def test_equivalent_with_no_windows_configured(self):
        sched = EcoScheduler(NBIConfig(), weekday_windows=[],
                             weekend_windows=[])
        batch = sched.decide_many([3600, 7200], self.NOW)
        assert all(d.tier == 0 and not d.deferred for d in batch)
        assert batch == [sched.next_window(d, self.NOW) for d in (3600, 7200)]

    def test_empty_batch(self):
        assert EcoScheduler(NBIConfig()).decide_many([], self.NOW) == []

    def test_rejects_nonpositive_duration(self):
        with pytest.raises(ValueError):
            EcoScheduler(NBIConfig()).decide_many([3600, 0], self.NOW)


class TestRunjobBatchCli:
    def test_from_file_array(self, tmp_path, capsys):
        from repro.cli.runjob import main

        cmds = tmp_path / "cmds.txt"
        cmds.write_text("echo one\n# skip\necho two\necho three\n")
        rc = main(["--no-eco", "--from-file", str(cmds), "--array",
                   "-n", "batch"])
        assert rc == 0
        ids = capsys.readouterr().out.strip().splitlines()
        assert len(ids) == 3
        assert all("_" in jid for jid in ids)
        assert len({jid.split("_")[0] for jid in ids}) == 1

    def test_from_file_without_array_submits_independently(self, tmp_path, capsys):
        from repro.cli.runjob import main

        cmds = tmp_path / "cmds.txt"
        cmds.write_text("echo one\necho two\n")
        rc = main(["--no-eco", "--from-file", str(cmds)])
        assert rc == 0
        ids = capsys.readouterr().out.strip().splitlines()
        assert len(ids) == 2
        assert all("_" not in jid for jid in ids)

    def test_array_requires_from_file(self, capsys):
        from repro.cli.runjob import main

        with pytest.raises(SystemExit):
            main(["--array", "echo", "x"])

    def test_dry_run_array_prints_coalesced_script(self, tmp_path, capsys):
        from repro.cli.runjob import main

        cmds = tmp_path / "cmds.txt"
        cmds.write_text("echo one\necho two\n")
        rc = main(["--no-eco", "--from-file", str(cmds), "--array",
                   "-n", "batch", "--dry-run"])
        assert rc == 0
        script = capsys.readouterr().out
        assert "#SBATCH --array=0-1" in script
        assert 'eval "${NBI_TASKS[$SLURM_ARRAY_TASK_ID]}"' in script


class TestWaitjobsThroughCache:
    def test_wait_for_cached_sim(self, sim):
        from repro.cli.waitjobs import wait_for

        SubmitEngine(sim).submit_many(homogeneous(4))
        cache = QueueCache(sim, ttl_s=3600.0)
        assert wait_for(cache, poll_s=30.0, timeout_s=0.0)
        assert Queue(backend=sim).ids() == []


class TestLaunchSubmitBatch:
    def test_mixed_jobs_and_launchers(self, sim, tmp_path):
        from repro.core import Kraken2
        from repro.launch.submit import submit_batch

        kraken = Kraken2(reads1="r1.fq", db=str(tmp_path), backend=sim,
                         outdir=str(tmp_path))
        result = submit_batch(homogeneous(3) + [kraken], backend=sim)
        assert len(result) == 4
        assert result.coalesced == 3
        assert result.sbatch_calls == 2  # 1 array + the kraken job
        manifest = tmp_path / "kraken2.manifest.json"
        assert manifest.exists()
