"""viewjobs TUI — the ViewModel state machine (no tty required).

Covers every interaction in the paper's Figure 1 caption: scrolling (arrow
and Vim keys), sorting, per-job details, column visibility/width, Space
selection and bulk cancel."""

from repro.cli.viewjobs import ViewModel
from repro.core import QueuedJob


def make_jobs(n=5):
    return [
        QueuedJob(jobid=str(100 + i), user=f"u{i % 2}", queue="main",
                  name=f"job{i}", state="RUNNING" if i % 2 else "PENDING",
                  time_left=f"0-0{i}:00:00", time_limit="1-00:00:00",
                  nodelist=f"n{i:03d}", cpus="4", memory="4096")
        for i in range(n)
    ]


def make_vm(jobs=None, cancelled=None):
    jobs = jobs if jobs is not None else make_jobs()
    state = {"jobs": list(jobs)}

    def source():
        return list(state["jobs"])

    def cancel(ids):
        (cancelled if cancelled is not None else []).extend(ids)
        state["jobs"] = [j for j in state["jobs"] if j.jobid not in set(ids)]

    vm = ViewModel(source, canceller=cancel)
    vm._test_state = state
    return vm


class TestNavigation:
    def test_vim_and_arrow_scrolling(self):
        vm = make_vm()
        assert vm.state.cursor == 0
        vm.keys("jjj")
        assert vm.state.cursor == 3
        vm.key("k")
        assert vm.state.cursor == 2
        vm.key("UP")
        assert vm.state.cursor == 1
        vm.key("DOWN")
        assert vm.state.cursor == 2
        vm.key("G")
        assert vm.state.cursor == 4
        vm.key("g")
        assert vm.state.cursor == 0

    def test_cursor_clamped(self):
        vm = make_vm(make_jobs(2))
        vm.keys("jjjjj")
        assert vm.state.cursor == 1

    def test_scroll_follows_cursor(self):
        vm = make_vm(make_jobs(50))
        vm.state.height = 10
        vm.key("G")
        assert vm.state.scroll == 40
        vm.key("g")
        assert vm.state.scroll == 0


class TestSorting:
    def test_sort_by_column_and_reverse(self):
        vm = make_vm()
        # move column cursor to JobName and sort
        vm.keys("lll")  # jobid → user → queue → name
        vm.key("s")
        assert vm.state.sort_key == "name"
        names = [j.name for j in vm.state.rows]
        assert names == sorted(names)
        vm.key("s")  # same column again → toggle desc
        assert vm.state.sort_desc
        assert [j.name for j in vm.state.rows] == sorted(names, reverse=True)

    def test_o_toggles_direction(self):
        vm = make_vm()
        ids = [j.jobid for j in vm.state.rows]
        vm.key("o")
        assert [j.jobid for j in vm.state.rows] == list(reversed(ids))


class TestColumns:
    def test_toggle_visibility(self):
        vm = make_vm()
        assert vm.state.visible["user"]
        vm.key("l")  # col cursor → user
        vm.key("v")
        assert not vm.state.visible["user"]
        header = vm.render()[0]
        assert "User" not in header
        vm.key("V")
        assert vm.state.visible["user"]

    def test_width_adjust(self):
        vm = make_vm()
        w0 = vm.state.widths["jobid"]
        vm.key(">")
        assert vm.state.widths["jobid"] == w0 + 2
        vm.keys("<<")
        assert vm.state.widths["jobid"] == w0 - 2

    def test_cannot_hide_last_column(self):
        vm = make_vm()
        for _ in range(20):
            vm.key("v")
        assert sum(vm.state.visible.values()) == 1


class TestSelectionAndCancel:
    def test_space_selects_and_advances(self):
        vm = make_vm()
        vm.key(" ")
        assert vm.state.selected == {"100"}
        assert vm.state.cursor == 1
        vm.key(" ")
        assert vm.state.selected == {"100", "101"}

    def test_space_toggles(self):
        vm = make_vm()
        vm.key(" ")
        vm.key("k")  # back to row 0
        vm.key(" ")
        assert vm.state.selected == set()

    def test_bulk_cancel_confirmed(self):
        cancelled = []
        vm = make_vm(cancelled=cancelled)
        vm.keys("  ")  # select rows 0 and 1
        vm.key("C")
        assert vm.state.mode == "confirm"
        vm.key("y")
        assert sorted(cancelled) == ["100", "101"]
        assert vm.state.mode == "list"
        assert len(vm.state.rows) == 3  # refreshed after cancel
        assert "cancelled 2 job(s)" in vm.render()[-2]

    def test_cancel_aborted(self):
        cancelled = []
        vm = make_vm(cancelled=cancelled)
        vm.key(" ")
        vm.key("C")
        vm.key("n")
        assert cancelled == []
        assert vm.state.selected == {"100"}  # selection kept on abort

    def test_cancel_cursor_row_when_none_selected(self):
        cancelled = []
        vm = make_vm(cancelled=cancelled)
        vm.key("j")
        vm.key("C")
        vm.key("y")
        assert cancelled == ["101"]

    def test_select_all_and_clear(self):
        vm = make_vm()
        vm.key("a")
        assert len(vm.state.selected) == 5
        vm.key("u")
        assert vm.state.selected == set()


class TestFilterAndDetails:
    def test_filter_narrows_rows(self):
        vm = make_vm()
        vm.key("f")
        for ch in "job3":
            vm.key(ch)
        vm.key("ENTER")
        assert [j.name for j in vm.state.rows] == ["job3"]
        vm.key("F")  # clear filter
        assert len(vm.state.rows) == 5

    def test_filter_escape_cancels(self):
        vm = make_vm()
        vm.key("f")
        vm.key("x")
        vm.key("ESC")
        assert vm.state.filter_text == ""
        assert len(vm.state.rows) == 5

    def test_filter_backspace(self):
        vm = make_vm()
        vm.keys("f")
        for ch in "ab":
            vm.key(ch)
        vm.key("BACKSPACE")
        assert vm.state.filter_text == "a"

    def test_details_view(self):
        vm = make_vm()
        vm.key("ENTER")
        assert vm.state.mode == "details"
        lines = "\n".join(vm.render())
        assert "job 100" in lines and "Partition" in lines
        vm.key("q")
        assert vm.state.mode == "list"

    def test_selection_survives_refresh(self):
        vm = make_vm()
        vm.key(" ")
        vm.key("r")
        assert vm.state.selected == {"100"}


class TestRender:
    def test_render_shows_all_rows_and_footer(self):
        vm = make_vm()
        lines = vm.render()
        assert any("job0" in ln for ln in lines)
        assert "5 job(s)" in lines[-2]
        assert "q:quit" in lines[-1]

    def test_render_marks_cursor_and_selection(self):
        vm = make_vm()
        vm.key(" ")  # select row0, cursor row1
        lines = vm.render()
        assert lines[1].startswith(" *")  # row0 selected
        assert lines[2].startswith(">")  # row1 cursor

    def test_quit(self):
        vm = make_vm()
        vm.key("q")
        assert vm.state.quit


class TestBulkCancelEndToEnd:
    """Satellite: the select → cancel → queue-refresh path driven against a
    real SimCluster through the same Queue/cancel plumbing ``viewjobs.main``
    wires up — not a stubbed source."""

    def make_cluster_vm(self, n=6):
        from datetime import datetime

        from repro.core import Job, Opts, Queue, SimCluster

        sim = SimCluster(now=datetime(2026, 3, 18, 10, 0),
                         default_user="testuser")
        for i in range(n):
            job = Job(name=f"work-{i}", command="sleep 600",
                      opts=Opts.new(threads=1, memory="1GB", time="1h"),
                      sim_duration_s=600)
            job.prepare()
            sim.submit(job)

        def source():
            return list(Queue(backend=sim))

        return sim, ViewModel(source, canceller=sim.cancel)

    def test_select_cancel_refresh(self):
        sim, vm = self.make_cluster_vm()
        assert len(vm.state.rows) == 6
        vm.keys("  ")  # select rows 0 and 1 (Space advances the cursor)
        vm.key("j")
        vm.key(" ")  # and row 3
        targets = set(vm.state.selected)
        assert len(targets) == 3
        vm.key("C")
        assert vm.state.mode == "confirm"
        assert set(vm.state.pending_cancel) == targets
        vm.key("y")
        # the simulator really cancelled them ...
        for jid in targets:
            assert sim.get(jid).state == "CANCELLED"
        # ... and the post-cancel refresh dropped them from the view
        assert vm.state.mode == "list"
        assert len(vm.state.rows) == 3
        assert targets.isdisjoint({j.jobid for j in vm.state.rows})
        assert vm.state.selected == set()
        assert "cancelled 3 job(s)" in vm.state.status

    def test_abort_leaves_cluster_untouched(self):
        sim, vm = self.make_cluster_vm(3)
        vm.key("a")  # select all
        vm.key("C")
        vm.key("n")  # abort at the confirm prompt
        assert all(j.state in ("RUNNING", "PENDING")
                   for j in sim.accounting())
        assert len(vm.state.rows) == 3

    def test_cancelled_jobs_are_archived_with_energy(self, tmp_path):
        """The cancel path feeds the accounting loop: partial runtime is
        charged and collected."""
        from repro.accounting import HistoryStore, collect

        sim, vm = self.make_cluster_vm(2)
        sim.advance(120)  # two minutes of real burn
        vm.refresh()
        vm.key("a")
        vm.key("C")
        vm.key("y")
        store = HistoryStore(tmp_path / "h.jsonl")
        assert collect(sim, store) == 2
        for rec in store.scan():
            assert rec.state == "CANCELLED"
            assert rec.runtime_s == 120
            assert rec.energy_kwh > 0
