"""Checkpointing: atomic roundtrip, async, retention, integrity, elastic."""

import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_tree, save_tree
from repro.checkpoint.manager import MANIFEST


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((16,)), jnp.bfloat16),
        },
        "opt": [jnp.zeros((8, 16)), jnp.asarray(3, jnp.int32)],
        "step": jnp.asarray(7, jnp.int32),
    }


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestRoundtrip:
    def test_save_restore(self, tmp_path):
        t = tree()
        save_tree(tmp_path / "c", t, extra={"cursor": 42})
        out, extra = restore_tree(tmp_path / "c", t)
        assert_tree_equal(t, out)
        assert extra == {"cursor": 42}
        # dtype preservation incl. bf16
        assert out["params"]["b"].dtype == jnp.bfloat16

    def test_restore_into_abstract_target(self, tmp_path):
        t = tree()
        save_tree(tmp_path / "c", t)
        target = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t
        )
        out, _ = restore_tree(tmp_path / "c", target)
        assert_tree_equal(t, out)

    def test_structure_mismatch_raises(self, tmp_path):
        save_tree(tmp_path / "c", tree())
        with pytest.raises(ValueError, match="leaves"):
            restore_tree(tmp_path / "c", {"only": jnp.zeros(3)})

    def test_shape_mismatch_raises(self, tmp_path):
        save_tree(tmp_path / "c", tree())
        bad = tree()
        bad["params"]["w"] = jnp.zeros((9, 16))
        with pytest.raises(ValueError, match="shape"):
            restore_tree(tmp_path / "c", bad)

    def test_corruption_detected(self, tmp_path):
        save_tree(tmp_path / "c", tree())
        rec = json.loads((tmp_path / "c" / MANIFEST).read_text())
        victim = tmp_path / "c" / rec["leaves"][0]["file"]
        raw = bytearray(victim.read_bytes())
        raw[0] ^= 0xFF  # torn page
        victim.write_bytes(bytes(raw))
        with pytest.raises(IOError, match="checksum"):
            restore_tree(tmp_path / "c", tree())


class TestManager:
    def test_latest_and_retention(self, tmp_path):
        m = CheckpointManager(tmp_path, keep=2)
        for step in (10, 20, 30):
            m.save(step, tree(step))
        assert m.latest_step() == 30
        assert m.all_steps() == [20, 30]  # 10 was GC'd

    def test_async_save(self, tmp_path):
        m = CheckpointManager(tmp_path)
        m.save(5, tree(), extra={"cursor": 5}, blocking=False)
        m.wait()
        out, extra, step = m.restore(tree())
        assert step == 5 and extra["cursor"] == 5

    def test_async_snapshot_isolated_from_donation(self, tmp_path):
        """The async writer must see the values at call time even if the
        caller immediately mutates/donates its arrays (training loop)."""
        m = CheckpointManager(tmp_path)
        t = {"w": np.ones((4,), np.float32)}
        m.save(1, t, blocking=False)
        t["w"][:] = 999.0  # simulate buffer reuse
        m.wait()
        out, _, _ = m.restore({"w": np.zeros((4,), np.float32)})
        np.testing.assert_array_equal(np.asarray(out["w"]), np.ones(4))

    def test_restore_specific_step(self, tmp_path):
        m = CheckpointManager(tmp_path, keep=5)
        m.save(1, tree(1))
        m.save(2, tree(2))
        out, _, step = m.restore(tree(), step=1)
        assert step == 1
        assert_tree_equal(out, tree(1))

    def test_no_checkpoint_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            CheckpointManager(tmp_path).restore(tree())

    def test_crashed_save_invisible(self, tmp_path):
        """A .tmp dir from a crashed writer is never listed as a step."""
        m = CheckpointManager(tmp_path)
        m.save(3, tree())
        (tmp_path / "step_000000099.tmp").mkdir()
        assert m.all_steps() == [3]
        m.save(4, tree())  # gc clears orphan tmp dirs
        assert not (tmp_path / "step_000000099.tmp").exists()


class TestElastic:
    def test_restore_to_different_sharding(self, tmp_path):
        """Elastic rescale: save replicated, restore with explicit shardings
        (1-device CPU: single-device shardings — the placement API is what
        the multi-host path uses)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        t = tree()
        save_tree(tmp_path / "c", t)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), t)
        out, _ = restore_tree(tmp_path / "c", t, shardings=sh)
        assert_tree_equal(t, out)
        for leaf in jax.tree_util.tree_leaves(out):
            assert leaf.sharding == NamedSharding(mesh, P())

    def test_train_resume_after_dp_resize(self, tmp_path):
        """Full elastic drill: train 4 steps at global_batch=8, 'lose half the
        cluster', resume the same run at global_batch=4 — state restores and
        training continues."""
        from repro.launch.train import build_argparser, train
        import repro.configs.nbi100m as mod

        orig = mod.config
        mod.config = lambda: orig().replace(
            name="nano", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
            head_dim=16, d_ff=64, vocab_size=256,
        )
        try:
            a1 = build_argparser().parse_args([
                "--arch", "nbi-100m", "--steps", "4", "--global-batch", "8",
                "--seq", "16", "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
                "--log-every", "2",
            ])
            r1 = train(a1)
            assert r1["completed_steps"] == 4
            a2 = build_argparser().parse_args([
                "--arch", "nbi-100m", "--steps", "6", "--global-batch", "4",
                "--seq", "16", "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
                "--log-every", "2",
            ])
            r2 = train(a2)
            assert r2["completed_steps"] == 6
            assert all(np.isfinite(m["loss"]) for m in r2["metrics"])
        finally:
            mod.config = orig
