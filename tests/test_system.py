"""End-to-end system tests: the full NBI-Slurm workflow over the simulator,
including the TPU-era path (submit a training job → sim executes the real
trainer → checkpoints appear → manifest patched)."""

import json
import sys
from pathlib import Path

from repro.cli import nbilaunch, runjob, waitjobs
from repro.core import Manifest, Pipeline, Queue, SimCluster, get_backend
from repro.core.job import Job
from repro.core.resources import Opts


class TestBioinformaticsWorkflow:
    def test_submit_wait_complete(self, capsys):
        """runjob → queue shows it → waitjobs blocks → queue drains."""
        rc = runjob.main(["-n", "wf", "--no-eco", "-c", "2", "-m", "1", "true"])
        assert rc == 0
        be = get_backend()
        assert len(Queue(name="wf", backend=be)) == 1
        assert waitjobs.main(["-n", "wf", "--quiet", "--poll", "60"]) == 0
        assert len(Queue(name="wf", backend=be)) == 0

    def test_eco_job_runs_at_window(self):
        """--eco defers; advancing the sim clock to the window starts it."""
        from datetime import datetime

        be = get_backend()
        be.now = datetime(2026, 3, 18, 10, 0)
        rc = runjob.main(["-n", "eco-job", "-t", "2", "--eco",
                          "--now", "2026-03-18T10:00:00", "sleep 100"])
        assert rc == 0
        j = Queue(name="eco-job", backend=be).jobs[0]
        assert j.state == "PENDING" and j.reason == "BeginTime"
        be.advance(to=datetime(2026, 3, 19, 0, 0, 1))
        j = Queue(name="eco-job", backend=be).jobs[0]
        assert j.state == "RUNNING"


class TestTrainingJobEndToEnd:
    def test_sim_executes_real_training_script(self, tmp_path, monkeypatch):
        """The flagship integration: nbilaunch-style submission whose script
        actually runs `python -m repro.launch.train` (tiny config) inside the
        simulator; afterwards the checkpoint exists on disk and the manifest
        records success."""
        monkeypatch.setenv("NBI_TMPDIR", str(tmp_path / "scripts"))
        sim = SimCluster(execute=True)
        ckpt = tmp_path / "ckpt"
        src = Path(__file__).resolve().parent.parent / "src"

        cmd = (
            f"{sys.executable} -m repro.launch.train --arch nbi-100m --smoke "
            f"--steps 4 --global-batch 2 --seq 16 --ckpt-dir {ckpt} "
            f"--ckpt-every 2 --log-every 2"
        )
        manifest = Manifest(str(tmp_path / "train.manifest.json"), tool="train")
        job = Job(name="train-nbi100m", command=cmd,
                  opts=Opts.new(threads=2, memory="4GB", time="1h"),
                  sim_duration_s=10)
        job.prelude = [f"export PYTHONPATH={src}"] + manifest.trailer_lines()
        jid = job.run(sim)
        manifest.write_submitted(jid)
        sim.run_until_idle()

        rec = Manifest.load(manifest.path)
        assert rec["status"] == "completed", rec
        from repro.checkpoint import CheckpointManager

        assert CheckpointManager(ckpt).latest_step() == 4

    def test_failure_requeue_then_resume(self, tmp_path, monkeypatch):
        """Interrupted run → requeued rerun resumes from the checkpoint.

        The simulator executes scripts at completion time, so 'interrupted
        mid-run' is modelled as a first submission that only reaches step 3
        before its node dies (requeue drill in test_simcluster), followed by
        the requeued rerun of the same command reaching step 6. The rerun
        must RESUME (checkpoint continues 3 → 6, not restart from 0)."""
        monkeypatch.setenv("NBI_TMPDIR", str(tmp_path / "scripts"))
        sim = SimCluster(execute=True)
        ckpt = tmp_path / "ckpt"
        src = Path(__file__).resolve().parent.parent / "src"
        log1, log2 = tmp_path / "run1.log", tmp_path / "run2.log"

        def train_cmd(steps, log):
            return (
                f"{sys.executable} -m repro.launch.train --arch nbi-100m "
                f"--smoke --steps {steps} --global-batch 2 --seq 16 "
                f"--ckpt-dir {ckpt} --ckpt-every 3 --log-every 3 > {log} 2>&1"
            )

        j1 = Job(name="run1", command=train_cmd(3, log1),
                 opts=Opts.new(threads=2, memory="4GB", time="2h"),
                 sim_duration_s=60)
        j1.prelude = [f"export PYTHONPATH={src}"]
        id1 = j1.run(sim)
        sim.run_until_idle()
        assert sim.get(id1).state == "COMPLETED"
        from repro.checkpoint import CheckpointManager

        assert CheckpointManager(ckpt).latest_step() == 3

        # "node died; Slurm requeues the job" → same command, full step count
        j2 = Job(name="run2", command=train_cmd(6, log2),
                 opts=Opts.new(threads=2, memory="4GB", time="2h"),
                 sim_duration_s=60)
        j2.prelude = [f"export PYTHONPATH={src}"]
        id2 = j2.run(sim)
        sim.run_until_idle()
        assert sim.get(id2).state == "COMPLETED"
        assert "resumed from step 3" in log2.read_text()
        assert CheckpointManager(ckpt).latest_step() == 6

    def test_train_pipeline_with_eval_step(self, tmp_path, monkeypatch):
        """Pipeline: train → 'eval' (reads the checkpoint) via afterok."""
        monkeypatch.setenv("NBI_TMPDIR", str(tmp_path / "scripts"))
        sim = SimCluster(execute=True)
        ckpt = tmp_path / "ckpt"
        src = Path(__file__).resolve().parent.parent / "src"

        train_cmd = (
            f"{sys.executable} -m repro.launch.train --arch nbi-100m --smoke "
            f"--steps 2 --global-batch 2 --seq 16 --ckpt-dir {ckpt} "
            f"--ckpt-every 2 --log-every 2"
        )
        eval_cmd = f"test -d {ckpt}/step_000000002 && echo ok > {tmp_path}/eval.txt"
        p = Pipeline("train-eval", backend=sim)
        t = Job(name="train", command=train_cmd,
                opts=Opts.new(threads=2, memory="4GB", time="1h"),
                sim_duration_s=10)
        t.prelude = [f"export PYTHONPATH={src}"]
        p.add("train", t)
        p.add("eval", Job(name="eval", command=eval_cmd,
                          opts=Opts.new(threads=1, memory="1GB", time="1h"),
                          sim_duration_s=5), after="train")
        p.run()
        sim.run_until_idle()
        states = {j.name: j.state for j in sim.accounting()}
        assert states == {"train": "COMPLETED", "eval": "COMPLETED"}
        assert (tmp_path / "eval.txt").read_text().strip() == "ok"
