"""Property pin: vectorized ``Placer.place_many`` ≡ scalar ``place_spec``.

``place_spec`` (the readable per-job loop) is the specification;
``place_many`` (the numpy batch path the SubmitEngine drives) must be
**bit-identical** to running it once per spec in the same order — same
chosen cluster, same wait/carbon floats, same tie-breaks, same candidate
tuples, same in-flight charge state afterwards. Any divergence means the
fast path changed placement behaviour, which these tests exist to catch.

The randomized pin runs everywhere; a `hypothesis` variant widens the
search when the library is present (CI), and is skipped cleanly when not.
"""

from __future__ import annotations

import random
from datetime import datetime, timedelta

import pytest

from repro.core import (
    ClusterHandle,
    ClusterRegistry,
    Job,
    Opts,
    Placer,
    SimCluster,
    SimNode,
)
from repro.core.eco import CarbonTrace

T0 = datetime(2026, 3, 18, 10, 0, 0)


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------


def random_trace(rng: random.Random) -> "CarbonTrace | None":
    roll = rng.random()
    if roll < 0.25:
        return None  # member without a carbon trace (carbon sorts last)
    length = rng.choice([24, 168])
    return CarbonTrace([round(rng.uniform(20.0, 600.0), 3) for _ in range(length)])


def random_registry(rng: random.Random, *, with_queues: bool = True) -> ClusterRegistry:
    handles = []
    n_members = rng.randint(2, 5)
    for i in range(n_members):
        name = f"c{i}"
        nodes = rng.randint(1, 3)
        cpus = rng.choice([4, 8, 16, 32])
        mem = rng.choice([8192, 32768, 131072])
        backend = SimCluster(
            nodes=[SimNode(f"{name}-n{k}", cpus=cpus, memory_mb=mem)
                   for k in range(nodes)],
            now=T0,
            default_user="testuser",
            name=name,
        )
        handles.append(ClusterHandle(
            name=name, kind="sim", backend=backend,
            carbon_trace=random_trace(rng),
            nodes=nodes, cpus_per_node=cpus, memory_mb_per_node=mem,
        ))
    reg = ClusterRegistry(handles)
    if with_queues:
        # live backlogs: some running, some pending, so the snapshot walk
        # has real running-remaining and pending-limit spans to sum
        for h in handles:
            for j in range(rng.randint(0, 6)):
                h.backend.submit(Job(
                    name=f"bg-{h.name}-{j}", command="sleep",
                    opts=Opts(threads=rng.randint(1, h.cpus_per_node),
                              memory_mb=1024,
                              time_s=rng.choice([600, 3600, 14400])),
                    sim_duration_s=rng.randrange(300, 7200),
                ))
            h.backend.advance(rng.choice([0, 45, 230]))
    return reg


def random_specs(rng: random.Random, n: int) -> list:
    specs = []
    for i in range(n):
        roll = rng.random()
        if roll < 0.08:
            cpus, mem = 4096, 10**9  # infeasible everywhere
        elif roll < 0.16:
            cpus, mem = rng.choice([4, 8, 16, 32]), 1024  # edge: == node size
        else:
            cpus, mem = rng.randint(1, 40), rng.choice([512, 4096, 65536])
        specs.append({
            "cpus": cpus,
            "memory_mb": mem,
            "time_s": rng.choice([60, 1800, 3600, 5401, 43200]),
            "name": rng.choice(["", f"job-{i}", "sweep-7", "align"]),
            "tool": rng.choice(["", "kraken2"]),
            "eco": rng.random() < 0.5,
        })
    return specs


def random_now(rng: random.Random) -> datetime:
    return T0 + timedelta(
        seconds=rng.randrange(0, 7 * 86400), microseconds=rng.randrange(0, 10**6)
    )


def scalar_reference(placer: Placer, specs, now, *, charge=True) -> list:
    """The spec: one place_spec call per spec, in order."""
    return [
        placer.place_spec(
            cpus=int(s.get("cpus", 1)),
            memory_mb=int(s.get("memory_mb", 0)),
            time_s=int(s.get("time_s", 3600)),
            now=now,
            name=s.get("name", ""),
            tool=s.get("tool", ""),
            eco=bool(s.get("eco", False)),
            charge=charge,
        )
        for s in specs
    ]


def assert_identical(vec, ref):
    assert len(vec) == len(ref)
    for i, (v, r) in enumerate(zip(vec, ref)):
        assert v.cluster == r.cluster, f"spec {i}: cluster {v.cluster} != {r.cluster}"
        assert v.wait_s == r.wait_s, f"spec {i}: wait {v.wait_s!r} != {r.wait_s!r}"
        assert v.carbon_gco2_kwh == r.carbon_gco2_kwh, f"spec {i}: carbon differs"
        assert v.eco == r.eco, f"spec {i}: eco flag differs"
        assert v.candidates == r.candidates, f"spec {i}: candidates differ"


def run_pin(seed: int, *, n_specs: int = 40, precharge: bool = False):
    rng = random.Random(seed)
    registry = random_registry(rng)
    vec_placer = Placer(registry)
    ref_placer = Placer(registry)
    if precharge:
        for h in registry:
            if rng.random() < 0.5:
                amount = float(rng.randrange(1, 10**6))
                vec_placer._inflight[h.name] = amount
                ref_placer._inflight[h.name] = amount
    specs = random_specs(rng, n_specs)
    now = random_now(rng)
    vec = vec_placer.place_many(specs, now)
    ref = scalar_reference(ref_placer, specs, now)
    assert_identical(vec, ref)
    assert vec_placer._inflight == ref_placer._inflight
    assert vec_placer.placements == ref_placer.placements == len(specs)


# ---------------------------------------------------------------------------
# the pin
# ---------------------------------------------------------------------------


class TestVectorizedPin:
    @pytest.mark.parametrize("seed", range(25))
    def test_place_many_matches_scalar(self, seed):
        run_pin(seed)

    @pytest.mark.parametrize("seed", range(25, 35))
    def test_with_precharged_inflight(self, seed):
        run_pin(seed, precharge=True)

    def test_empty_batch(self):
        placer = Placer(random_registry(random.Random(0)))
        assert placer.place_many([], T0) == []
        assert placer._inflight == {}

    def test_single_spec_batches(self):
        # batch of one == one scalar call, across many random worlds
        for seed in range(10):
            run_pin(1000 + seed, n_specs=1)

    def test_uncharged_probes_leave_no_state(self):
        rng = random.Random(7)
        registry = random_registry(rng)
        vec_placer, ref_placer = Placer(registry), Placer(registry)
        specs = random_specs(rng, 20)
        vec = vec_placer.place_many(specs, T0, charge=False)
        ref = scalar_reference(ref_placer, specs, T0, charge=False)
        assert_identical(vec, ref)
        assert vec_placer._inflight == ref_placer._inflight == {}

    def test_all_infeasible_fall_back_to_every_member(self):
        rng = random.Random(13)
        registry = random_registry(rng, with_queues=False)
        placer = Placer(registry)
        specs = [{"cpus": 10**6, "memory_mb": 10**12, "time_s": 3600}]
        [p] = placer.place_many(specs, T0)
        assert len(p.candidates) == len(registry)

    def test_with_predictor_history(self, tmp_path):
        """Predictor-refined durations must flow through both paths the
        same way (duration affects span hours, charge, and carbon)."""
        from repro.accounting import HistoryStore, RuntimePredictor

        store = HistoryStore(tmp_path / "h.jsonl")
        from repro.accounting import JobRecord

        store.append_many([
            JobRecord(jobid=str(i), name=f"align-{i}", user="testuser",
                      state="COMPLETED", runtime_s=900 + i * 10)
            for i in range(6)
        ])
        predictor = RuntimePredictor(store)
        rng = random.Random(21)
        registry = random_registry(rng)
        vec_placer = Placer(registry, predictor=predictor)
        ref_placer = Placer(registry, predictor=predictor)
        specs = random_specs(rng, 30) + [
            {"cpus": 2, "memory_mb": 1024, "time_s": 43200, "name": "align-99",
             "tool": "", "eco": True},
        ]
        now = random_now(rng)
        assert_identical(
            vec_placer.place_many(specs, now),
            scalar_reference(ref_placer, specs, now),
        )
        assert vec_placer._inflight == ref_placer._inflight

    def test_numpy_fallback_is_the_scalar_loop(self, monkeypatch):
        import repro.core.federation as fed

        monkeypatch.setattr(fed, "_np", None)
        run_pin(3)  # place_many now IS the scalar loop; must still agree


# ---------------------------------------------------------------------------
# hypothesis variant (runs where hypothesis is installed, e.g. CI)
# ---------------------------------------------------------------------------


try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment-dependent
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    class TestVectorizedPinHypothesis:
        @given(seed=st.integers(min_value=0, max_value=2**32 - 1),
               n=st.integers(min_value=1, max_value=60),
               precharge=st.booleans())
        @settings(max_examples=60, deadline=None,
                  suppress_health_check=[HealthCheck.too_slow])
        def test_place_many_matches_scalar(self, seed, n, precharge):
            run_pin(seed, n_specs=n, precharge=precharge)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_variant_skipped():
        pass  # pragma: no cover - placeholder so the skip is visible
