"""Data pipeline: determinism, host sharding, resume, straggler backup."""

import threading
import time

import numpy as np
import pytest

from repro.data import (
    DataLoader, SyntheticLMDataset, host_shard_for, make_train_loader,
)


class TestDataset:
    def test_deterministic(self):
        ds = SyntheticLMDataset(1024, seed=3)
        a = ds.batch(7, 4, 32)
        b = ds.batch(7, 4, 32)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_different_indices_differ(self):
        ds = SyntheticLMDataset(1024, seed=3)
        a, b = ds.batch(1, 4, 32), ds.batch(2, 4, 32)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_labels_are_shifted_tokens(self):
        ds = SyntheticLMDataset(512, seed=0)
        full = ds.tokens(0, 2, 16)
        b = ds.batch(0, 2, 16)
        np.testing.assert_array_equal(b["tokens"], full[:, :-1])
        np.testing.assert_array_equal(b["labels"], full[:, 1:])

    def test_tokens_in_vocab(self):
        ds = SyntheticLMDataset(100, seed=1)
        t = ds.batch(0, 8, 64)["tokens"]
        assert t.min() >= 0 and t.max() < 100

    def test_learnable_structure(self):
        """~half the transitions are prev+1 (the Markov phrase pattern)."""
        ds = SyntheticLMDataset(1000, seed=0)
        t = ds.tokens(0, 16, 256)
        frac = np.mean(t[:, 1:] == (t[:, :-1] + 1) % 1000)
        assert 0.4 < frac < 0.6


class TestHostSharding:
    def test_union_of_shards_is_global_batch(self):
        ds = SyntheticLMDataset(512, seed=9)
        global_rows, seq, hosts = 8, 16, 4
        full = ds.batch(3, global_rows, seq)
        parts = []
        for h in range(hosts):
            sh = host_shard_for(global_rows, h, hosts)
            parts.append(ds.batch(3, sh.rows, seq, row_offset=sh.row_offset))
        stacked = np.concatenate([p["tokens"] for p in parts], axis=0)
        np.testing.assert_array_equal(stacked, full["tokens"])

    def test_divisibility_enforced(self):
        with pytest.raises(ValueError):
            host_shard_for(10, 0, 3)
        with pytest.raises(ValueError):
            host_shard_for(8, 4, 4)


class TestLoader:
    def test_in_order_iteration(self):
        seen = []
        loader = DataLoader(lambda i: {"i": i}, prefetch=3, workers=2)
        for _ in range(10):
            seen.append(next(loader)["i"])
        loader.close()
        assert seen == list(range(10))

    def test_resume_from_state(self):
        loader = DataLoader(lambda i: i, prefetch=2)
        next(loader), next(loader), next(loader)
        state = loader.state_dict()
        loader.close()
        loader2 = DataLoader(lambda i: i, prefetch=2)
        loader2.load_state_dict(state)
        assert next(loader2) == 3
        loader2.close()

    def test_backup_fetch_beats_straggler(self):
        """Attempt 0 of batch 2 hangs; the backup (attempt 1) must win."""
        release = threading.Event()

        def hook(idx, attempt):
            if idx == 2 and attempt == 0:
                release.wait(timeout=5)  # simulated stuck NFS read

        loader = DataLoader(
            lambda i: i, prefetch=1, workers=2, straggler_ms=50, fetch_hook=hook
        )
        out = [next(loader) for _ in range(4)]
        release.set()
        assert out == [0, 1, 2, 3]
        assert loader.stats["backups"] >= 1
        assert loader.stats["backup_wins"] >= 1
        loader.close()

    def test_results_identical_with_and_without_straggler(self):
        ds = SyntheticLMDataset(256, seed=5)
        fetch = lambda i: ds.batch(i, 2, 8)

        plain = DataLoader(fetch, prefetch=2)
        a = [next(plain) for _ in range(5)]
        plain.close()

        slow_once = {"done": False}

        def hook(idx, attempt):
            if idx == 1 and attempt == 0 and not slow_once["done"]:
                slow_once["done"] = True
                time.sleep(0.3)

        delayed = DataLoader(fetch, prefetch=2, straggler_ms=30, fetch_hook=hook)
        b = [next(delayed) for _ in range(5)]
        delayed.close()
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x["tokens"], y["tokens"])

    def test_make_train_loader_end_to_end(self):
        loader = make_train_loader(512, 8, 16, seed=0, host_index=1, host_count=2)
        batch = next(loader)
        assert batch["tokens"].shape == (4, 16)
        loader.close()
