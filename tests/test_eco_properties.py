"""Property-based tests of the EcoScheduler invariants (hypothesis).

The paper defines a strict three-tier preference. These properties pin the
invariants for arbitrary window configurations, durations and clock times:

  P1. the chosen start is never in the past (≥ now + min_delay);
  P2. the chosen start always lies inside an eco window (tiers 1-3);
  P3. tier 1 ⇒ the job finishes inside its window AND never touches peak;
  P4. tier ≤ 2 ⇒ the job span never overlaps a peak window;
  P5. optimality: no candidate start strictly earlier than the chosen one
      achieves a strictly better tier (the scheduler returns the best
      achievable tier, earliest-first);
  P6. determinism: same inputs → same decision.
"""

from datetime import datetime, timedelta

import pytest

hypothesis = pytest.importorskip("hypothesis")
given, settings, st = hypothesis.given, hypothesis.settings, hypothesis.strategies

from repro.core import EcoScheduler


def windows_strategy(max_windows=2):
    """Sorted, non-overlapping minute-of-day windows."""

    @st.composite
    def _windows(draw):
        n = draw(st.integers(0, max_windows))
        points = draw(
            st.lists(
                st.integers(0, 24 * 60), min_size=2 * n, max_size=2 * n, unique=True
            )
        )
        points.sort()
        return [(points[2 * i], points[2 * i + 1]) for i in range(n)
                if points[2 * i + 1] > points[2 * i]]

    return _windows()


clock = st.datetimes(
    min_value=datetime(2026, 1, 1), max_value=datetime(2026, 12, 1)
).map(lambda d: d.replace(microsecond=0))
duration = st.integers(min_value=60, max_value=3 * 86400)


@st.composite
def scheds(draw):
    return EcoScheduler(
        weekday_windows=draw(windows_strategy()),
        weekend_windows=draw(windows_strategy()),
        peak_hours=draw(windows_strategy(1)),
        horizon_days=draw(st.integers(1, 10)),
        min_delay_s=draw(st.sampled_from([0, 600, 3600])),
    )


def overlaps_peak(sched, start, dur_s):
    end = start + timedelta(seconds=dur_s)
    return any(
        ps < end and start < pe
        for ps, pe in sched._absolute_peak_windows(start, end)
    )


@settings(max_examples=200, deadline=None)
@given(sched=scheds(), now=clock, dur=duration)
def test_invariants(sched, now, dur):
    d = sched.next_window(dur, now)
    # P6 determinism
    d2 = sched.next_window(dur, now)
    assert d == d2

    if d.tier == 0:
        assert not d.deferred and d.begin == now
        return

    # P1: never in the past / before the min delay
    assert d.begin >= now + timedelta(seconds=sched.min_delay_s)

    # P2: start lies inside its eco window
    assert d.window_start <= d.begin < d.window_end
    assert sched.in_eco_window(d.begin)

    end = d.begin + timedelta(seconds=dur)
    if d.tier == 1:
        # P3: completes inside the window, avoids peak
        assert end <= d.window_end
        assert not overlaps_peak(sched, d.begin, dur)
    elif d.tier == 2:
        # P4: avoids peak (but may overrun the window)
        assert not overlaps_peak(sched, d.begin, dur)
    else:
        # tier 3 exists only when it does overlap peak
        assert overlaps_peak(sched, d.begin, dur)


@settings(max_examples=100, deadline=None)
@given(sched=scheds(), now=clock, dur=duration)
def test_best_tier_is_achieved(sched, now, dur):
    """P5: the returned tier equals the minimum tier over all candidates."""
    d = sched.next_window(dur, now)
    cands = sched._candidates(dur, now)
    if not cands:
        assert d.tier == 0
        return
    assert d.tier == min(c.tier for c in cands)
    # earliest-of-best-tier (no carbon trace configured)
    best = [c for c in cands if c.tier == d.tier]
    assert d.begin == best[0].start


@settings(max_examples=100, deadline=None)
@given(now=clock, dur=st.integers(60, 6 * 3600))
def test_default_config_always_finds_window(now, dur):
    """With the paper's default windows, any ≤6h job gets tier 1 within 14d."""
    sched = EcoScheduler(
        weekday_windows=[(0, 360)],
        weekend_windows=[(0, 420), (660, 960)],
        peak_hours=[(1020, 1200)],
        horizon_days=14,
        min_delay_s=0,
    )
    d = sched.next_window(dur, now)
    assert d.tier == 1


@settings(max_examples=150, deadline=None)
@given(sched=scheds(), now=clock, dur=duration,
       name=st.sampled_from(["blast-1", "align_7", "kraken2", "x"]),
       user=st.sampled_from(["", "alice", "bob"]))
def test_empty_history_predictor_is_bit_identical(tmp_path_factory, sched,
                                                  now, dur, name, user):
    """P7 (accounting): with an EMPTY HistoryStore attached, the
    predictor-aware entry points — decide() and decide_many(keys=...) —
    return decisions bit-identical to the plain scheduler for arbitrary
    window configs, clocks, durations and job identities."""
    from repro.accounting import HistoryStore, RuntimePredictor

    store = HistoryStore(tmp_path_factory.mktemp("acct") / "empty.jsonl")
    pred_sched = EcoScheduler(
        weekday_windows=sched.weekday_windows,
        weekend_windows=sched.weekend_windows,
        peak_hours=sched.peak_hours,
        horizon_days=sched.horizon_days,
        min_delay_s=sched.min_delay_s,
        predictor=RuntimePredictor(store),
    )
    assert pred_sched.decide(dur, now, name=name, user=user) == \
        sched.next_window(dur, now)
    assert pred_sched.decide_many([dur, dur * 2], now,
                                  keys=[(name, user), (name, user)]) == \
        sched.decide_many([dur, dur * 2], now)


@settings(max_examples=150, deadline=None)
@given(sched=scheds(), now=clock, dur=duration,
       name=st.sampled_from(["blast-1", "align_7", "kraken2", "x"]))
def test_controller_plan_is_bit_identical_to_static_path(sched, now, dur, name):
    """P8 (eco v2): hold-and-release is a pure *mechanism* swap. For
    arbitrary window configs, clocks, durations and job identities the
    EcoController's plan — whose ``begin`` becomes the release deadline —
    equals the static path's ``next_window`` decision exactly. So with no
    controller attached nothing changes, and with one attached a held job's
    worst-case start (the deadline) is the static ``--begin`` verbatim."""
    from repro.core import EcoController, SimCluster

    controller = EcoController(SimCluster(now=now), sched)
    static = sched.next_window(dur, now)
    planned = controller.plan(dur, now, name=name)
    assert planned == static
    # registering uses the plan's begin as the deadline, unchanged
    controller.register("999", planned, now=now, duration_s=dur)
    if static.deferred:
        assert controller.held["999"].deadline == static.begin
    else:
        assert "999" not in controller.held
