"""NBI::Manifest — JSON provenance: written at submit, patched in place by
the job itself on completion/failure, no jq (paper §Wrappers)."""

import json
from pathlib import Path

from repro.core import Job, Manifest, Opts


class TestLifecycle:
    def test_write_submitted(self, tmp_path):
        m = Manifest(
            str(tmp_path / "m.json"),
            tool="kraken2", version="2.1.3",
            inputs={"reads1": "r1.fq"}, params={"threads": 8},
            outputs={"report": "out/report.txt"},
            resources={"memory_mb": 1024},
        )
        path = m.write_submitted(jobid=42)
        rec = json.loads(Path(path).read_text())
        assert rec["status"] == "submitted"
        assert rec["jobid"] == 42
        assert rec["tool"] == "kraken2"
        assert rec["inputs"]["reads1"] == "r1.fq"
        assert rec["submitted_at"] is not None
        assert rec["finished_at"] is None

    def test_patch_in_place(self, tmp_path):
        m = Manifest(str(tmp_path / "m.json"), tool="t")
        m.write_submitted(1)
        Manifest.patch(str(tmp_path / "m.json"), status="completed", exit_status=0)
        rec = Manifest.load(str(tmp_path / "m.json"))
        assert rec["status"] == "completed"
        assert rec["exit_status"] == 0
        assert rec["tool"] == "t"  # untouched fields survive

    def test_trailer_uses_no_jq(self):
        m = Manifest("/data/out/m.json")
        trailer = "\n".join(m.trailer_lines())
        assert "jq" not in trailer  # paper: no external tools like jq
        assert "python3 -c" in trailer
        assert "trap" in trailer


class TestEndToEnd:
    def _job_with_manifest(self, tmp_path, command):
        m = Manifest(str(tmp_path / "m.json"), tool="demo")
        job = Job(name="demo", command=command,
                  opts=Opts.new(threads=1, memory="1GB", time="1h"),
                  sim_duration_s=5)
        job.prelude = m.trailer_lines()
        return m, job

    def test_job_patches_on_success(self, exec_sim, tmp_path, monkeypatch):
        monkeypatch.setenv("NBI_TMPDIR", str(tmp_path / "s"))
        m, job = self._job_with_manifest(tmp_path, "true")
        jid = job.run(exec_sim)
        m.write_submitted(jid)
        assert Manifest.load(m.path)["status"] == "submitted"
        exec_sim.run_until_idle()
        rec = Manifest.load(m.path)
        assert rec["status"] == "completed"
        assert rec["exit_status"] == 0
        assert rec["finished_at"] is not None

    def test_job_patches_on_failure(self, exec_sim, tmp_path, monkeypatch):
        """Failures are recorded too (the trap fires on any exit)."""
        monkeypatch.setenv("NBI_TMPDIR", str(tmp_path / "s"))
        m, job = self._job_with_manifest(tmp_path, "exit 7")
        jid = job.run(exec_sim)
        m.write_submitted(jid)
        exec_sim.run_until_idle()
        rec = Manifest.load(m.path)
        assert rec["status"] == "failed"
        assert rec["exit_status"] == 7
