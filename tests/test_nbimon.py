"""nbimon CLI: live ticker (native bus + polling adapter), snapshot and
textfile flows, the exposition validator's exit codes, and the
``--stats`` flag on waitjobs/viewjobs.
"""

import json

import pytest

from repro.cli import nbimon, waitjobs
from repro.core import events as ev
from repro.core.job import Job
from repro.core.resources import Opts
from repro.obs import metrics as m


@pytest.fixture(autouse=True)
def _obs_disabled():
    m.disable()
    yield
    m.disable()


def make_job(name="j", *, duration=60):
    opts = Opts.new(threads=1, memory="1GB", time="1h")
    return Job(name=name, command="true", opts=opts, sim_duration_s=duration)


class TestLiveTicker:
    def test_native_bus_runs_until_drained(self, sim):
        make_job(name="watched", duration=120).run(sim)
        lines = []
        tracer = nbimon.live_ticker(sim, poll_s=60.0, ticks=50,
                                    out=lines.append)
        assert tracer.finished == 1 and not tracer.open
        assert any(ev.COMPLETED in ln for ln in lines)
        assert any("watched" in ln for ln in lines)
        assert len(sim.bus) == 0  # ticker + tracer both unsubscribed

    def test_adapter_path_without_bus(self, sim):
        class BusLess:
            """Backend shaped like real SLURM: queue()/get(), no bus."""

            def __init__(self, inner):
                self._inner = inner

            def queue(self):
                return self._inner.queue()

            def get(self, jobid):
                return self._inner.get(jobid)

        make_job(duration=60).run(sim)
        lines = []
        tracer = nbimon.live_ticker(
            BusLess(sim), ticks=3, poll_s=60.0, out=lines.append,
            sleep=lambda s: sim.advance(s),
        )
        assert tracer.finished == 1
        assert any(ev.COMPLETED in ln for ln in lines)

    def test_duration_converts_to_ticks(self, sim):
        ticked = []
        nbimon.live_ticker(sim, duration_s=120.0, poll_s=60.0,
                           out=ticked.append)
        # empty queue: the sim loop drains immediately, no hang


class TestMainFlows:
    def _populated_registry(self):
        reg = m.enable()
        reg.counter("nbi_t_total", "t", labels=("cluster",)) \
            .labels(cluster="green").inc(2)
        reg.histogram("nbi_t_seconds", "t").observe(0.5)
        return reg

    def test_default_prometheus_dump(self, capsys):
        self._populated_registry()
        assert nbimon.main([]) == 0
        out = capsys.readouterr().out
        assert 'nbi_t_total{cluster="green"} 2' in out

    def test_json_snapshot(self, capsys):
        self._populated_registry()
        assert nbimon.main(["--json"]) == 0
        snap = json.loads(capsys.readouterr().out)
        assert snap["metrics"]["nbi_t_total"]["series"][0]["value"] == 2.0

    def test_textfile_write_and_check(self, capsys, tmp_path):
        self._populated_registry()
        prom = tmp_path / "nbi.prom"
        assert nbimon.main(["--textfile", str(prom)]) == 0
        assert prom.is_file()
        capsys.readouterr()
        assert nbimon.main(["--check-textfile", str(prom)]) == 0
        assert capsys.readouterr().out.startswith("ok:")

    def test_snapshot_file_rendering(self, capsys, tmp_path):
        from repro.obs.export import write_snapshot

        reg = m.MetricsRegistry()
        reg.gauge("nbi_g", "g").set(7)
        path = tmp_path / "snap.json"
        write_snapshot(path, reg, meta={"jobs": 1})
        assert nbimon.main(["--json", "--snapshot", str(path)]) == 0
        snap = json.loads(capsys.readouterr().out)
        assert snap["meta"]["jobs"] == 1
        assert snap["metrics"]["nbi_g"]["series"][0]["value"] == 7.0

    def test_check_rejects_malformed(self, capsys, tmp_path):
        bad = tmp_path / "bad.prom"
        bad.write_text("nbi_x NaN\n")
        assert nbimon.main(["--check-textfile", str(bad)]) == 1
        assert "invalid textfile" in capsys.readouterr().err

    def test_check_missing_file(self, capsys, tmp_path):
        assert nbimon.main(
            ["--check-textfile", str(tmp_path / "absent.prom")]
        ) == 1

    def test_live_json_summary(self, capsys):
        from repro.core import get_queue_cache

        make_job(name="lv", duration=60).run(get_queue_cache())
        assert nbimon.main(["--live", "--json", "--poll", "60"]) == 0
        stats = json.loads(capsys.readouterr().out)
        assert stats["trace"]["spans_finished"] == 1
        assert "registry" in stats  # --live enables metrics


class TestStatsFlag:
    def test_waitjobs_stats_json(self, capsys):
        from repro.core import get_queue_cache

        backend = get_queue_cache()
        jid = str(make_job(name="ws", duration=60).run(backend))
        rc = waitjobs.main([jid, "--json", "--stats", "--poll", "60"])
        payload = json.loads(capsys.readouterr().out)
        assert rc == 0 and payload["jobs"][jid] == "COMPLETED"
        assert "queue_cache" in payload["stats"]
        assert "registry" in payload["stats"]  # --stats enabled metrics

    def test_waitjobs_stats_text(self, capsys):
        rc = waitjobs.main(["--stats", "--quiet", "-u", "nobody"])
        out = capsys.readouterr().out
        assert rc == 0 and '"queue_cache"' in out

    def test_viewjobs_once_stats(self, capsys):
        from repro.cli import viewjobs
        from repro.core import get_queue_cache

        make_job(name="vs", duration=60).run(get_queue_cache())
        rc = viewjobs.main(["--once", "--stats"])
        out = capsys.readouterr().out
        assert rc == 0 and '"queue_cache"' in out and '"registry"' in out
