"""NBI::Opts semantics: human-friendly parsing → SLURM units (paper §Opts)."""

import pytest
hypothesis = pytest.importorskip("hypothesis")
given, st = hypothesis.given, hypothesis.strategies

from repro.core import Opts, format_slurm_time, parse_memory_mb, parse_time_s


class TestMemoryParsing:
    @pytest.mark.parametrize(
        "value,mb",
        [
            (64, 64),  # bare numbers are MB (SLURM convention)
            ("8GB", 8192),
            ("8gb", 8192),
            ("8G", 8192),
            ("500 MB", 500),
            ("500", 500),
            ("1.5G", 1536),
            ("1TB", 1024 * 1024),
            ("2048k", 2),
        ],
    )
    def test_values(self, value, mb):
        assert parse_memory_mb(value) == mb

    @pytest.mark.parametrize("bad", ["", "abc", "12XB", "-5", 0, -1, "0"])
    def test_rejects(self, bad):
        with pytest.raises(ValueError):
            parse_memory_mb(bad)


class TestTimeParsing:
    @pytest.mark.parametrize(
        "value,seconds",
        [
            (12, 12 * 3600),  # paper: -t 12 = 12 hours
            (0.5, 1800),
            ("2h30m", 9000),
            ("1d2h", 93600),
            ("90s", 90),
            ("45m", 2700),
            ("0-12:00:00", 12 * 3600),  # SLURM D-HH:MM:SS
            ("2-00:00:00", 2 * 86400),
            ("2-12:30", 2 * 86400 + 12 * 3600 + 1800),
            ("12:30:15", 12 * 3600 + 30 * 60 + 15),
            ("12:30", 12 * 3600 + 1800),
            ("6", 6 * 3600),
        ],
    )
    def test_values(self, value, seconds):
        assert parse_time_s(value) == seconds

    @pytest.mark.parametrize("bad", ["", "abc", "2x30m", 0, -3])
    def test_rejects(self, bad):
        with pytest.raises(ValueError):
            parse_time_s(bad)

    def test_format_roundtrip(self):
        assert format_slurm_time(12 * 3600) == "0-12:00:00"
        assert format_slurm_time(2 * 86400 + 3600 + 61) == "2-01:01:01"

    @given(st.integers(min_value=1, max_value=30 * 86400))
    def test_format_parse_roundtrip(self, seconds):
        assert parse_time_s(format_slurm_time(seconds)) == seconds


class TestOpts:
    def test_paper_example_directives(self):
        """runjob -n assembly -c 18 -m 64 -t 12 → exact sbatch lines."""
        opts = Opts.new(threads=18, memory="64GB", time=12, output_dir="./logs/")
        lines = opts.sbatch_directives("assembly")
        assert "#SBATCH --job-name=assembly" in lines
        assert "#SBATCH --cpus-per-task=18" in lines
        assert "#SBATCH --mem=65536" in lines
        assert "#SBATCH --time=0-12:00:00" in lines
        assert "#SBATCH --output=./logs/assembly.%j.out" in lines

    def test_begin_directive(self):
        opts = Opts.new(threads=1, memory="1GB", time="1h")
        opts.set_begin("2026-03-19T00:00:00")
        assert "#SBATCH --begin=2026-03-19T00:00:00" in opts.sbatch_directives()

    def test_array_directives(self):
        opts = Opts.new(threads=1)
        opts.array_size = 200
        opts.array_throttle = 10
        lines = opts.sbatch_directives("align")
        assert "#SBATCH --array=0-199%10" in lines
        assert any("%A_%a.out" in ln for ln in lines)

    def test_dependencies(self):
        opts = Opts.new(threads=1)
        opts.dependencies = [11, 12]
        assert "#SBATCH --dependency=afterok:11:12" in opts.sbatch_directives()

    def test_email_default_type(self):
        opts = Opts.new(email="a@b.c")
        lines = opts.sbatch_directives()
        assert "#SBATCH --mail-user=a@b.c" in lines
        assert "#SBATCH --mail-type=END" in lines

    def test_chainable_setters(self):
        opts = Opts().set_memory("2GB").set_time("2h30m")
        assert opts.memory_mb == 2048
        assert opts.time_s == 9000

    def test_view(self):
        v = Opts.new(queue="fast", threads=4, memory="8GB", time=2).view()
        assert "queue=fast" in v and "8GB" in v and "0-02:00:00" in v

    @given(
        mb=st.integers(min_value=1, max_value=10**7),
        secs=st.integers(min_value=60, max_value=10 * 86400),
        threads=st.integers(min_value=1, max_value=512),
    )
    def test_directives_always_render(self, mb, secs, threads):
        opts = Opts(threads=threads, memory_mb=mb, time_s=secs)
        lines = opts.sbatch_directives("x")
        assert f"#SBATCH --mem={mb}" in lines
        assert f"#SBATCH --cpus-per-task={threads}" in lines
