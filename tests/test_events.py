"""Event-driven core: EventBus, SimCluster emission, PollingEventAdapter,
event-invalidated QueueCache, EventCollector, event-driven waitjobs.

The tentpole invariant throughout: subscribers are backend-agnostic — the
simulator's native events and the adapter's synthetic ones carry the same
vocabulary, so every consumer (waitjobs, TUI, accounting) works unchanged
against either backend.
"""

import json
from datetime import datetime, timedelta

from repro.core import (
    EventBus,
    Job,
    JobEvent,
    Opts,
    PollingEventAdapter,
    Queue,
    QueueCache,
    SimCluster,
    diff_snapshots,
    terminal_event_for_state,
)
from repro.core import events as ev

T0 = datetime(2026, 3, 18, 10, 0, 0)


def make_job(name="j", *, cpus=1, time="1h", duration=60, hold=False, **kw):
    opts = Opts.new(threads=cpus, memory="1GB", time=time)
    opts.hold = hold
    return Job(name=name, command="true", opts=opts, sim_duration_s=duration, **kw)


class TestEventBus:
    def test_subscribe_emit_unsubscribe(self):
        bus = EventBus()
        seen = []
        token = bus.subscribe(seen.append)
        e = JobEvent(type=ev.SUBMITTED, jobid="1", at=T0)
        bus.emit(e)
        assert seen == [e]
        bus.unsubscribe(token)
        bus.emit(e)
        assert len(seen) == 1
        assert bus.emitted == 2 and bus.delivered == 1

    def test_type_filter(self):
        bus = EventBus()
        terminal = []
        bus.subscribe(terminal.append, types=ev.TERMINAL_EVENTS)
        bus.emit(JobEvent(type=ev.STARTED, jobid="1", at=T0))
        bus.emit(JobEvent(type=ev.COMPLETED, jobid="1", at=T0))
        assert [e.type for e in terminal] == [ev.COMPLETED]

    def test_subscriber_error_is_isolated(self):
        bus = EventBus()
        seen = []

        def boom(e):
            raise RuntimeError("bad subscriber")

        bus.subscribe(boom)
        bus.subscribe(seen.append)
        bus.emit(JobEvent(type=ev.STARTED, jobid="1", at=T0))
        assert len(seen) == 1  # delivery continued past the failure
        assert len(bus.errors) == 1

    def test_subscriber_error_counted_and_delivery_completes(self):
        """A failing subscriber is swallowed, counted in the obs registry,
        and every later subscriber in the same emit still receives."""
        from repro.obs import metrics as obs_metrics

        reg = obs_metrics.enable(obs_metrics.MetricsRegistry())
        try:
            bus = EventBus()
            before, after = [], []

            def boom(e):
                raise RuntimeError("bad subscriber")

            bus.subscribe(before.append)
            bus.subscribe(boom)
            bus.subscribe(after.append)
            e1 = JobEvent(type=ev.STARTED, jobid="1", at=T0)
            e2 = JobEvent(type=ev.COMPLETED, jobid="1", at=T0)
            bus.emit(e1)
            bus.emit(e2)
            assert before == [e1, e2] and after == [e1, e2]
            assert [type(x).__name__ for _, x in bus.errors] == \
                ["RuntimeError", "RuntimeError"]
            fam = reg.get("nbi_bus_subscriber_errors_total")
            assert fam.labels(type=ev.STARTED).value == 1
            assert fam.labels(type=ev.COMPLETED).value == 1
        finally:
            obs_metrics.disable()

    def test_history_ring(self):
        bus = EventBus(history=4)
        for i in range(10):
            bus.emit(JobEvent(type=ev.STARTED, jobid=str(i), at=T0))
        assert [e.jobid for e in bus.history] == ["6", "7", "8", "9"]


class TestTerminalStateMapping:
    def test_exact_states(self):
        assert terminal_event_for_state("COMPLETED") == ev.COMPLETED
        assert terminal_event_for_state("FAILED") == ev.FAILED
        assert terminal_event_for_state("TIMEOUT") == ev.TIMEOUT
        assert terminal_event_for_state("NODE_FAIL") == ev.NODE_FAIL

    def test_sacct_decorations(self):
        assert terminal_event_for_state("CANCELLED by 1234") == ev.CANCELLED
        assert terminal_event_for_state("OUT_OF_ME+") == ev.FAILED
        assert terminal_event_for_state("OUT_OF_MEMORY") == ev.FAILED

    def test_unknown_means_completed(self):
        assert terminal_event_for_state("") == ev.COMPLETED
        assert terminal_event_for_state("MYSTERY") == ev.COMPLETED


class TestSimClusterEmission:
    def test_lifecycle_events_in_order(self, sim):
        seen = []
        sim.bus.subscribe(lambda e: seen.append((e.type, e.jobid)))
        jid = make_job(duration=120).run(sim)
        sim.advance(300)
        assert seen == [
            (ev.SUBMITTED, str(jid)),
            (ev.STARTED, str(jid)),
            (ev.COMPLETED, str(jid)),
        ]

    def test_event_carries_job_facts(self, sim):
        seen = []
        sim.bus.subscribe(seen.append, types=[ev.STARTED])
        make_job(name="facts").run(sim)
        e = seen[0]
        assert e.name == "facts" and e.user == "testuser"
        assert e.state == "RUNNING" and e.node and e.at == sim.now

    def test_timeout_and_failure_events(self, sim):
        seen = []
        sim.bus.subscribe(lambda e: seen.append(e.type))
        make_job(time="1m", duration=3600).run(sim)
        sim.advance(7200)
        assert ev.TIMEOUT in seen

    def test_cancel_event(self, sim):
        seen = []
        sim.bus.subscribe(lambda e: seen.append(e.type), types=[ev.CANCELLED])
        jid = make_job(duration=9999).run(sim)
        sim.cancel([jid])
        assert seen == [ev.CANCELLED]

    def test_node_fail_and_requeue_events(self, sim):
        seen = []
        sim.bus.subscribe(lambda e: seen.append((e.type, e.jobid)))
        j1 = make_job(name="survivor", duration=9999)
        j2 = make_job(name="fragile", duration=9999)
        j2.opts.requeue = False
        id1, id2 = j1.run(sim), j2.run(sim)
        node1 = sim.get(id1).node
        node2 = sim.get(id2).node
        sim.fail_node(node1)
        if node2 != node1:
            sim.fail_node(node2)
        types = [t for t, _ in seen]
        assert ev.REQUEUED in types and ev.NODE_FAIL in types

    def test_array_tasks_emit_individually(self, sim):
        seen = []
        sim.bus.subscribe(lambda e: seen.append(e.jobid), types=[ev.SUBMITTED])
        job = Job(name="arr", command="echo #FILE#",
                  opts=Opts.new(threads=1, memory="1GB", time="1h"),
                  files=["a", "b", "c"], sim_duration_s=30)
        base = job.run(sim)
        assert seen == [f"{base}_0", f"{base}_1", f"{base}_2"]


class TestHoldRelease:
    def test_held_job_stays_pending(self, sim):
        jid = make_job(hold=True).run(sim)
        j = sim.get(jid)
        assert j.state == "PENDING" and j.reason == ev.HELD_REASON
        sim.advance(3600)
        assert j.state == "PENDING"

    def test_release_starts_and_emits(self, sim):
        seen = []
        sim.bus.subscribe(lambda e: seen.append(e.type))
        jid = make_job(hold=True, duration=60).run(sim)
        sim.release([jid])
        j = sim.get(jid)
        assert j.state == "RUNNING"
        assert seen == [ev.SUBMITTED, ev.RELEASED, ev.STARTED]

    def test_release_is_idempotent_and_targeted(self, sim):
        jid = make_job(hold=True).run(sim)
        other = make_job(name="free", duration=9999).run(sim)
        sim.release([jid])
        sim.release([jid])  # second release: no-op, no error
        released = [e for e in sim.bus.history if e.type == ev.RELEASED]
        assert len(released) == 1
        assert sim.get(other).state == "RUNNING"  # untouched

    def test_hold_renders_sbatch_directive(self):
        job = make_job(hold=True)
        assert "#SBATCH --hold" in job.script()

    def test_queue_row_shows_held_reason(self, sim):
        make_job(hold=True).run(sim)
        rows = sim.queue()
        assert rows[0]["state"] == "PENDING"
        assert rows[0]["reason"] == ev.HELD_REASON


class TestDiffSnapshots:
    def row(self, jid, state="PENDING", reason=""):
        return {"jobid": jid, "name": "n", "user": "u", "state": state,
                "reason": reason, "nodelist": ""}

    def test_first_poll_is_baseline(self):
        assert diff_snapshots(None, {"1": self.row("1")}, T0) == []

    def test_new_job_submitted(self):
        out = diff_snapshots({}, {"1": self.row("1")}, T0)
        assert [e.type for e in out] == [ev.SUBMITTED]

    def test_new_running_job_also_started(self):
        out = diff_snapshots({}, {"1": self.row("1", "RUNNING")}, T0)
        assert [e.type for e in out] == [ev.SUBMITTED, ev.STARTED]

    def test_pending_to_running_is_started(self):
        out = diff_snapshots({"1": self.row("1")},
                             {"1": self.row("1", "RUNNING")}, T0)
        assert [e.type for e in out] == [ev.STARTED]

    def test_running_to_pending_is_requeued(self):
        out = diff_snapshots({"1": self.row("1", "RUNNING")},
                             {"1": self.row("1", "PENDING")}, T0)
        assert [e.type for e in out] == [ev.REQUEUED]

    def test_hold_cleared_is_released(self):
        out = diff_snapshots(
            {"1": self.row("1", "PENDING", ev.HELD_REASON)},
            {"1": self.row("1", "PENDING", "Resources")}, T0)
        assert [e.type for e in out] == [ev.RELEASED]

    def test_vanished_job_terminal_with_unresolved_state(self):
        out = diff_snapshots({"1": self.row("1", "RUNNING")}, {}, T0)
        assert len(out) == 1 and out[0].is_terminal and out[0].state == ""

    def test_no_change_no_events(self):
        snap = {"1": self.row("1", "RUNNING")}
        assert diff_snapshots(snap, dict(snap), T0) == []


class TestPollingEventAdapter:
    def test_synthesises_same_vocabulary_as_sim(self, sim):
        """A subscriber cannot tell adapter events from native ones."""
        adapter = PollingEventAdapter(sim, clock=lambda: sim.now)
        adapter.poll()
        native, synthetic = [], []
        sim.bus.subscribe(lambda e: native.append(e.type))
        adapter.bus.subscribe(lambda e: synthetic.append(e.type))
        make_job(duration=60).run(sim)
        adapter.poll()
        sim.advance(120)
        adapter.poll()
        assert synthetic == native == [ev.SUBMITTED, ev.STARTED, ev.COMPLETED]

    def test_terminal_state_resolved_through_backend(self, sim):
        adapter = PollingEventAdapter(sim, clock=lambda: sim.now)
        adapter.poll()
        make_job(time="1m", duration=7200).run(sim)
        adapter.poll()
        sim.advance(7200)
        (e,) = adapter.poll()
        assert e.type == ev.TIMEOUT and e.state == "TIMEOUT"

    def test_repeat_polls_emit_nothing_new(self, sim):
        adapter = PollingEventAdapter(sim, clock=lambda: sim.now)
        adapter.poll()
        make_job().run(sim)
        adapter.poll()
        assert adapter.poll() == [] and adapter.poll() == []
        assert adapter.polls == 4


class TestQueueCacheEventInvalidation:
    def test_snapshot_dropped_on_direct_backend_mutation(self, sim):
        """A writer going straight to the simulator — not through the cache
        — must still invalidate the snapshot, via the event bus."""
        cache = QueueCache(sim, ttl_s=3600.0)
        assert cache.queue() == []
        make_job(duration=9999).run(sim)  # direct submit, cache bypassed
        assert len(cache.queue()) == 1  # event invalidated the snapshot
        assert cache.event_invalidations >= 1

    def test_quiet_cluster_serves_from_snapshot(self, sim):
        cache = QueueCache(sim, ttl_s=3600.0)
        make_job(duration=9999).run(sim)
        cache.queue()
        polls = cache.polls
        for _ in range(5):
            cache.queue()
        assert cache.polls == polls and cache.hits >= 5

    def test_shared_cache_binds_sim_bus(self, sim):
        from repro.core import get_queue_cache

        cache = get_queue_cache(sim)
        assert cache.queue() == []
        make_job(duration=9999).run(sim)
        assert len(cache.queue()) == 1

    def test_unbind_and_reset_do_not_leak_subscriptions(self, sim):
        from repro.core import get_queue_cache, reset_queue_cache

        cache = get_queue_cache(sim)
        subs_before = len(sim.bus)
        reset_queue_cache()
        assert len(sim.bus) == subs_before - 1  # unsubscribed, not leaked
        cache.unbind_bus()  # idempotent
        assert len(sim.bus) == subs_before - 1


class TestEventCollector:
    def test_archives_each_terminal_job_once(self, sim, tmp_path):
        from repro.accounting import EventCollector, HistoryStore

        store = HistoryStore(tmp_path / "h.jsonl")
        coll = EventCollector(sim, store).attach(sim.bus)
        for i in range(5):
            make_job(name=f"c{i}", duration=60).run(sim)
        sim.advance(600)
        coll.flush()
        assert coll.collected == 5
        assert len(store.ids()) == 5
        # replaying the same terminal set adds nothing (dedup in memory)
        coll.flush()
        assert len(store.ids()) == 5

    def test_no_archive_rescans_after_attach(self, sim, tmp_path):
        """collect() scans the archive every call; the collector only once."""
        from repro.accounting import EventCollector, HistoryStore

        store = HistoryStore(tmp_path / "h.jsonl")
        scans = {"n": 0}
        orig = store.ids

        def counting_ids():
            scans["n"] += 1
            return orig()

        store.ids = counting_ids
        coll = EventCollector(sim, store).attach(sim.bus)
        for i in range(3):
            make_job(duration=30).run(sim)
            sim.advance(60)
        coll.detach()
        assert scans["n"] == 1  # construction only
        assert len(store.ids()) == 3

    def test_records_match_batch_collect(self, sim, tmp_path):
        from repro.accounting import EventCollector, HistoryStore, collect

        ev_store = HistoryStore(tmp_path / "ev.jsonl")
        batch_store = HistoryStore(tmp_path / "batch.jsonl")
        coll = EventCollector(sim, ev_store).attach(sim.bus)
        make_job(name="same", cpus=4, duration=120).run(sim)
        sim.advance(600)
        coll.flush()
        collect(sim, batch_store)
        (a,), (b,) = list(ev_store.scan()), list(batch_store.scan())
        assert a == b


class TestEventDrivenWaitjobs:
    def test_sim_wait_uses_one_snapshot(self, sim):
        """The acceptance ratio: terminal events replace per-tick polls."""
        from repro.cli.waitjobs import wait_for_events

        class Counting:
            def __init__(self, inner):
                self.inner = inner
                self.calls = 0

            def queue(self):
                self.calls += 1
                return self.inner.queue()

            def __getattr__(self, name):
                return getattr(self.inner, name)

        for i in range(20):
            make_job(name=f"w{i}", duration=300 + 60 * i).run(sim)
        counting = Counting(sim)
        result = wait_for_events(counting, poll_s=60.0)
        assert result.ok and len(result.states) == 20
        assert all(s == "COMPLETED" for s in result.states.values())
        # one snapshot to resolve the watch set; events do the rest. The
        # polling path needed one snapshot per 60 s tick (~35 here).
        assert counting.calls == 1
        assert result.snapshots == 1

    def test_wait_reports_bad_states(self, sim):
        from repro.cli.waitjobs import wait_for_events

        make_job(name="bad", time="1m", duration=7200).run(sim)
        result = wait_for_events(sim, poll_s=600.0)
        assert result.ok
        assert list(result.states.values()) == ["TIMEOUT"]
        assert result.exit_code == 1

    def test_timeout_still_exits_2(self, sim):
        from repro.cli.waitjobs import wait_for_events

        make_job(name="forever", time="10h", duration=9 * 3600).run(sim)
        result = wait_for_events(sim, poll_s=0.001, timeout_s=0.05)
        assert not result.ok and result.exit_code == 2

    def test_explicit_id_already_gone_still_reported(self, sim):
        """An id that ended badly BEFORE the wait started must still drive
        the exit code, even while other watched ids are active."""
        from repro.cli.waitjobs import wait_for_events

        doomed = make_job(name="gonebad", time="1m", duration=7200).run(sim)
        sim.advance(7200)  # doomed TIMEOUTs and leaves the queue
        alive = make_job(name="alive", duration=60).run(sim)
        result = wait_for_events(sim, ids=[doomed, alive], poll_s=60.0)
        assert result.states[str(doomed)] == "TIMEOUT"
        assert result.states[str(alive)] == "COMPLETED"
        assert result.exit_code == 1

    def test_polling_path_baseline_race_resolves(self, sim):
        """A job that finishes between the watch snapshot and the adapter
        baseline must resolve instead of hanging the polling loop (the
        adapter's first poll yields no vanish events by definition)."""
        from repro.cli import waitjobs as wj

        jid = make_job(name="racer", duration=60).run(sim)

        class NonSim:  # hide the sim so the polling branch runs
            def __init__(self, inner):
                self._inner = inner
                self.first = True

            def queue(self):
                rows = self._inner.queue()
                if self.first:
                    self.first = False
                    return rows  # watch snapshot sees the job...
                self._inner.advance(120)  # ...then it finishes
                return self._inner.queue()

            def __getattr__(self, name):
                return getattr(self._inner, name)

        result = wj.wait_for_events(NonSim(sim), ids=[jid],
                                    poll_s=0.001, timeout_s=5.0)
        assert result.ok
        assert result.states[str(jid)] == "COMPLETED"


class TestWaitjobsCli:
    def test_json_output_and_exit_zero(self, capsys):
        from repro.cli import runjob, waitjobs

        runjob.main(["-n", "ok1", "--no-eco", "true"])
        capsys.readouterr()
        rc = waitjobs.main(["--json", "-n", "ok1"])
        payload = json.loads(capsys.readouterr().out)
        assert rc == 0
        assert payload["ok"] and not payload["timed_out"]
        assert list(payload["jobs"].values()) == ["COMPLETED"]
        assert payload["failed"] == []

    def test_exit_one_on_failure(self, capsys):
        from repro.cli import waitjobs
        from repro.core import get_backend

        be = get_backend()
        make_job(name="doomed", time="1m", duration=7200).run(be)
        rc = waitjobs.main(["--json", "-n", "doomed", "--poll", "600"])
        payload = json.loads(capsys.readouterr().out)
        assert rc == 1
        assert payload["failed"] and payload["exit_code"] == 1

    def test_plain_output_names_failures(self, capsys):
        from repro.cli import waitjobs
        from repro.core import get_backend

        be = get_backend()
        make_job(name="doomed2", time="1m", duration=7200).run(be)
        rc = waitjobs.main(["-n", "doomed2", "--poll", "600"])
        out = capsys.readouterr().out
        assert rc == 1 and "failed" in out


class TestLiveViewModel:
    def test_refreshes_only_on_events(self, sim):
        from repro.cli.viewjobs import ViewModel

        calls = {"n": 0}

        def source():
            calls["n"] += 1
            return [q for q in Queue(backend=sim)]

        vm = ViewModel(source)
        vm.bind_bus(sim.bus)
        base = calls["n"]
        assert vm.maybe_refresh() is False  # quiet cluster: no re-read
        assert calls["n"] == base
        make_job(duration=9999).run(sim)
        assert vm.maybe_refresh() is True
        assert calls["n"] == base + 1
        assert len(vm.state.rows) == 1

    def test_ticker_shows_last_event(self, sim):
        from repro.cli.viewjobs import ViewModel

        vm = ViewModel(lambda: list(Queue(backend=sim)))
        vm.bind_bus(sim.bus)
        jid = make_job(name="tick", duration=9999).run(sim)
        vm.maybe_refresh()
        footer = "\n".join(vm.render())
        assert "live:" in footer and str(jid) in footer

    def test_live_once_cli(self, capsys):
        from repro.cli import runjob, viewjobs

        runjob.main(["-n", "livejob", "--no-eco", "sleep 60"])
        capsys.readouterr()
        rc = viewjobs.main(["--once", "--live", "--all"])
        out = capsys.readouterr().out
        assert rc == 0 and "live:" in out
