"""Multi-host bootstrap: SLURM topology parsing + multinode sbatch."""

import pytest

from repro.launch.distributed import (
    _expand_first_host, coordinator_address, maybe_initialize,
    multinode_sbatch, slurm_topology,
)
from repro.launch.submit import TrainLauncher
from repro.core import SimCluster


class TestNodelist:
    @pytest.mark.parametrize(
        "nodelist,first",
        [
            ("n001", "n001"),
            ("n[001-004]", "n001"),
            ("n[001-004,007]", "n001"),
            ("n[17,19]", "n17"),
            ("gpu-a[01-02],gpu-b01", "gpu-a01"),
        ],
    )
    def test_first_host(self, nodelist, first):
        assert _expand_first_host(nodelist) == first

    def test_coordinator_address(self, monkeypatch):
        monkeypatch.setenv("SLURM_JOB_NODELIST", "tpu[004-007]")
        monkeypatch.setenv("SLURM_JOB_ID", "123456")
        addr = coordinator_address()
        assert addr.startswith("tpu004:")
        port = int(addr.split(":")[1])
        assert 20000 <= port < 30000

    def test_no_slurm_env(self, monkeypatch):
        monkeypatch.delenv("SLURM_JOB_NODELIST", raising=False)
        assert coordinator_address() is None


class TestTopology:
    def test_multi_task(self, monkeypatch):
        monkeypatch.setenv("SLURM_NTASKS", "8")
        monkeypatch.setenv("SLURM_PROCID", "3")
        assert slurm_topology() == (3, 8)

    def test_single_task_is_none(self, monkeypatch):
        monkeypatch.setenv("SLURM_NTASKS", "1")
        monkeypatch.setenv("SLURM_PROCID", "0")
        assert slurm_topology() is None

    def test_maybe_initialize_disabled(self, monkeypatch):
        monkeypatch.setenv("REPRO_DISABLE_DISTRIBUTED", "1")
        monkeypatch.setenv("SLURM_NTASKS", "8")
        monkeypatch.setenv("SLURM_PROCID", "3")
        assert maybe_initialize() == (0, 1)  # tests never touch jax.distributed

    def test_maybe_initialize_no_slurm(self, monkeypatch):
        monkeypatch.delenv("SLURM_NTASKS", raising=False)
        assert maybe_initialize() == (0, 1)


class TestSbatch:
    def test_multinode_script(self):
        s = multinode_sbatch(
            job_name="train-x", hosts=64, command="python -m repro.launch.train --arch x",
            time="2-00:00:00", gres="tpu:v5e:4", mem_mb=300_000,
        )
        assert "#SBATCH --nodes=64" in s
        assert "#SBATCH --ntasks=64" in s
        assert "#SBATCH --requeue" in s
        assert "srun --kill-on-bad-exit=1 python -m repro.launch.train" in s

    def test_trainlauncher_multinode(self):
        tl = TrainLauncher(arch="mistral-large-123b", eco=False,
                           backend=SimCluster())
        assert tl.sizing["hosts"] > 1
        assert tl.make_command().startswith("srun --kill-on-bad-exit=1 ")
        script = tl.sbatch_script()
        assert f"--nodes={tl.sizing['hosts']}" in script
        assert "--gres=tpu:v5e:4" in script

    def test_trainlauncher_single_host_no_srun(self):
        tl = TrainLauncher(arch="nbi-100m", eco=False, backend=SimCluster())
        assert tl.sizing["hosts"] == 1
        assert not tl.make_command().startswith("srun")
