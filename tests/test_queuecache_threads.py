"""QueueCache under concurrent readers (the gateway daemon's access
pattern: N connection threads hammering ``queue()`` while bus events
invalidate the snapshot).

The contract under test:

* **no torn snapshots** — every list a reader gets back is internally
  consistent (all rows from the same backend generation), even when an
  invalidation lands mid-refresh;
* **single-flight refresh** — one invalidation window costs exactly one
  real ``backend.queue()`` poll no matter how many readers race it;
* **monotonic staleness** — a reader never sees the generation go
  backwards.
"""

from __future__ import annotations

import threading
import time
from datetime import datetime

from repro.core.engine import QueueCache
from repro.core.events import EventBus, JobEvent


class GenerationBackend:
    """Backend whose rows are stamped with a generation counter.

    ``queue()`` reads the generation once, then sleeps mid-build before
    stamping the remaining rows — a deliberately wide window for a racing
    ``bump()`` to tear the snapshot if the cache ever let two refreshes
    (or a refresh and an invalidation-then-refresh) interleave.
    """

    def __init__(self, rows: int = 16):
        self.bus = EventBus()
        self.n_rows = rows
        self.generation = 0
        self.calls = 0

    def queue(self) -> list[dict]:
        self.calls += 1
        gen = self.generation
        out = [{"jobid": str(i), "gen": gen} for i in range(self.n_rows // 2)]
        time.sleep(0.003)  # hold the refresh open across a potential bump
        out += [{"jobid": str(i), "gen": gen}
                for i in range(self.n_rows // 2, self.n_rows)]
        return out

    def bump(self) -> None:
        """Advance the world and announce it (event-invalidates the cache)."""
        self.generation += 1
        self.bus.emit(JobEvent(type="COMPLETED", jobid=str(self.generation), at=datetime(2026, 3, 18)))


def _snapshot_gen(rows: list[dict]) -> int:
    """The snapshot's uniform generation; fails the test if it is torn."""
    gens = {r["gen"] for r in rows}
    assert len(gens) == 1, f"torn snapshot: mixed generations {sorted(gens)}"
    return gens.pop()


def test_concurrent_readers_single_flight_and_untorn():
    backend = GenerationBackend()
    cache = QueueCache(backend, ttl_s=3600.0)  # staleness is event-driven only

    n_readers = 8
    windows = 12
    stop = threading.Event()
    per_reader_gens: list[list[int]] = [[] for _ in range(n_readers)]
    errors: list[BaseException] = []

    def reader(slot: int):
        try:
            while not stop.is_set():
                per_reader_gens[slot].append(_snapshot_gen(cache.queue()))
        except BaseException as e:  # noqa: BLE001 — surfaced on the main thread
            errors.append(e)

    threads = [threading.Thread(target=reader, args=(i,), daemon=True)
               for i in range(n_readers)]
    for t in threads:
        t.start()

    deadline = time.monotonic() + 30.0
    for _ in range(windows):
        backend.bump()
        # wait for the refresh this window owes us, so windows never merge
        want = backend.generation
        while time.monotonic() < deadline:
            rows = cache._rows
            if rows is not None and rows[0]["gen"] == want:
                break
            time.sleep(0.001)
    stop.set()
    for t in threads:
        t.join(timeout=10.0)
    assert not errors, errors[0]

    # single-flight: the initial fill plus exactly one poll per window —
    # 8 racing readers must not multiply the refreshes
    assert backend.calls == windows + 1, (
        f"{backend.calls} backend polls for {windows} invalidation windows"
    )
    assert cache.polls == windows + 1
    total_reads = sum(len(g) for g in per_reader_gens)
    assert cache.hits == total_reads - cache.polls
    assert cache.event_invalidations == windows

    # every reader observed a monotonically non-decreasing world
    for slot, gens in enumerate(per_reader_gens):
        assert gens, f"reader {slot} never completed a read"
        assert all(a <= b for a, b in zip(gens, gens[1:])), (
            f"reader {slot} saw the generation go backwards"
        )
    # and the readers did collectively reach the final generation
    assert max(g[-1] for g in per_reader_gens) == windows


def test_event_invalidation_forces_repoll_within_ttl():
    """A bus event must drop the snapshot immediately — long before the
    TTL would — and the drop must cost exactly one re-poll."""
    backend = GenerationBackend(rows=4)
    cache = QueueCache(backend, ttl_s=3600.0)

    assert _snapshot_gen(cache.queue()) == 0
    backend.bump()
    assert _snapshot_gen(cache.queue()) == 1
    assert backend.calls == 2
    # no event since the refresh: served from the snapshot
    assert _snapshot_gen(cache.queue()) == 1
    assert backend.calls == 2


def test_reentrant_invalidation_from_refresh_thread():
    """A backend that emits events synchronously from inside ``queue()``
    (the simulator does on lazy transitions) must not deadlock the
    refreshing thread against its own lock."""
    backend = GenerationBackend(rows=2)
    original = backend.queue

    def chatty_queue():
        rows = original()
        backend.bus.emit(JobEvent(type="STARTED", jobid="x", at=datetime(2026, 3, 18)))  # re-enters cache
        return rows

    backend.queue = chatty_queue
    cache = QueueCache(backend, ttl_s=3600.0)
    assert _snapshot_gen(cache.queue()) == 0  # completes — no deadlock
    # the event was emitted BY the refresh, so it describes state the rows
    # already capture: the snapshot survives and the next read is a hit
    assert _snapshot_gen(cache.queue()) == 0
    assert backend.calls == 1
    assert cache.hits == 1
