"""EcoController — reactive hold-and-release (eco v2).

The contract under test, end to end against the simulator:

* the decision is the SAME EcoScheduler decision as the static path — its
  ``begin`` just becomes a release deadline instead of a ``--begin``;
* held jobs are released **no later** than that deadline (the static path
  is the worst case), and **earlier** when observed load is low inside an
  eco window with the span still off-peak;
* with no controller attached, nothing in the static path changes.
"""

from datetime import datetime, timedelta

from repro.core import (
    EcoController,
    EcoScheduler,
    Job,
    Opts,
    SimCluster,
    SimNode,
    SubmitEngine,
)

WED_10 = datetime(2026, 3, 18, 10, 0, 0)  # a Wednesday


def sched_nightly(**kw):
    """Night window 00:00-06:00, peak 17:00-20:00 (paper defaults, pinned)."""
    args = dict(
        weekday_windows=[(0, 360)],
        weekend_windows=[(0, 420), (660, 960)],
        peak_hours=[(1020, 1200)],
        horizon_days=14,
        min_delay_s=0,
    )
    args.update(kw)
    return EcoScheduler(**args)


def sched_with_midday():
    """Adds a 12:00-13:00 weekday window: tier-2 territory for long jobs —
    the early-release opportunity the night deadline would otherwise skip."""
    return sched_nightly(weekday_windows=[(0, 360), (720, 780)])


def eco_job(name="eco", *, hours=4, duration=600, cpus=1):
    return Job(name=name, command="true",
               opts=Opts.new(threads=cpus, memory="1GB", time=f"{hours}h"),
               sim_duration_s=duration)


def fresh_sim(**kw):
    return SimCluster(now=WED_10, default_user="testuser", **kw)


class TestPlanEqualsStaticDecision:
    def test_plan_is_next_window(self):
        sched = sched_nightly()
        c = EcoController(fresh_sim(), sched)
        for hours in (1, 4, 12):
            assert c.plan(hours * 3600, WED_10) == sched.next_window(
                hours * 3600, WED_10
            )

    def test_detached_static_path_sets_begin_not_hold(self):
        sim = fresh_sim()
        engine = SubmitEngine(sim, eco=True, scheduler=sched_nightly(),
                              now=WED_10, coalesce=False)
        job = eco_job()
        engine.submit_many([job])
        assert job.opts.begin and not job.opts.hold
        assert sim.get(job.jobid).held is False


class TestDeadlineRelease:
    def test_held_then_released_at_deadline(self):
        sim = fresh_sim()
        sched = sched_nightly()
        c = EcoController(sim, sched)
        job = eco_job(hours=4)
        jid = c.submit(job, now=WED_10)
        j = sim.get(jid)
        static = sched.next_window(4 * 3600, WED_10)
        assert j.held and j.state == "PENDING" and not job.opts.begin
        assert c.held[str(jid)].deadline == static.begin

        sim.advance(to=static.begin - timedelta(hours=1))
        assert j.state == "PENDING"  # nothing favourable yet: still held
        sim.advance(to=static.begin + timedelta(minutes=1))
        assert j.state in ("RUNNING", "COMPLETED")
        assert j.started_at == static.begin  # wake_at stops exactly there
        (rec,) = c.released
        assert rec.early is False and rec.at == rec.deadline

    def test_detach_stops_releases(self):
        sim = fresh_sim()
        sched = sched_nightly()
        c = EcoController(sim, sched)
        assert c.self_driving
        jid = c.submit(eco_job(hours=4), now=WED_10)
        c.detach()
        assert not c.self_driving and not sim.tick_hooks
        sim.advance(to=WED_10 + timedelta(days=1))
        assert sim.get(jid).state == "PENDING"  # nobody releasing any more

    def test_non_deferred_decision_runs_immediately(self):
        sim = SimCluster(now=datetime(2026, 3, 18, 1, 0, 0))  # inside window
        c = EcoController(sim, sched_nightly())
        jid = c.submit(eco_job(hours=2), now=sim.now)
        assert sim.get(jid).state == "RUNNING"
        assert not c.held


class TestEarlyRelease:
    def test_released_early_when_idle_in_window(self):
        sim = fresh_sim()
        sched = sched_with_midday()
        c = EcoController(sim, sched)
        jid = c.submit(eco_job(hours=4), now=WED_10)
        static = sched.next_window(4 * 3600, WED_10)
        assert static.begin.hour == 0  # tier 1 rules: deferred to the night
        # 12:30 same day: idle cluster, inside the midday eco window, and
        # a 4 h span from here stays clear of the 17:00 peak
        sim.advance(to=WED_10.replace(hour=12, minute=30))
        j = sim.get(jid)
        assert j.state in ("RUNNING", "COMPLETED")
        (rec,) = c.released
        assert rec.early and rec.at < rec.deadline
        assert rec.lead_s > 0

    def test_not_released_early_when_span_would_hit_peak(self):
        sim = fresh_sim()
        sched = sched_with_midday()
        c = EcoController(sim, sched)
        # 6 h from 12:xx ends past 17:00 — releasing early would break the
        # tier promise, so the controller waits for the night deadline
        jid = c.submit(eco_job(hours=6), now=WED_10)
        sim.advance(to=WED_10.replace(hour=12, minute=30))
        assert sim.get(jid).state == "PENDING"
        sim.advance(to=WED_10 + timedelta(days=1))
        assert sim.get(jid).state in ("RUNNING", "COMPLETED")

    def test_not_released_early_under_load(self):
        sim = fresh_sim(nodes=[SimNode("n000", cpus=4)])
        sched = sched_with_midday()
        c = EcoController(sim, sched, load_threshold=0.25)
        # 3 of 4 cpus busy all day: load 0.75 > threshold
        Job(name="hog", command="true",
            opts=Opts.new(threads=3, memory="1GB", time="24h"),
            sim_duration_s=23 * 3600).run(sim)
        jid = c.submit(eco_job(hours=4), now=WED_10)
        sim.advance(to=WED_10.replace(hour=12, minute=30))
        assert sim.get(jid).state == "PENDING"  # busy: keep holding
        deadline = c.held[str(jid)].deadline
        sim.advance(to=deadline)
        assert sim.get(jid).started_at is not None
        assert sim.get(jid).started_at <= deadline  # worst case preserved

    def test_event_triggers_release_when_load_drops(self):
        """The reactive part: a COMPLETED event inside a window frees the
        cluster and the very same tick releases the held job."""
        sim = fresh_sim(nodes=[SimNode("n000", cpus=4)])
        sched = sched_with_midday()
        c = EcoController(sim, sched, load_threshold=0.25)
        # hog fills the whole node until 12:10, inside the midday window
        Job(name="hog", command="true",
            opts=Opts.new(threads=4, memory="1GB", time="4h"),
            sim_duration_s=int(2 * 3600 + 10 * 60)).run(sim)
        # 4 h job: tier 1 puts its deadline at the NIGHT window, but a 4 h
        # span from ~12:10 stays off-peak, so low load may pull it forward
        jid = c.submit(eco_job(hours=4, duration=300), now=WED_10)
        assert c.held[str(jid)].deadline.hour == 0
        sim.advance(to=WED_10.replace(hour=12, minute=45))
        j = sim.get(jid)
        # released at the hog's completion instant (12:10) — an event
        # boundary, not a poll boundary or the deadline
        assert j.started_at == WED_10.replace(hour=12, minute=10)
        (rec,) = c.released
        assert rec.early


class TestLoadFraction:
    def test_counts_up_nodes_only(self):
        sim = fresh_sim(nodes=[SimNode("a", cpus=10), SimNode("b", cpus=10)])
        c = EcoController(sim, sched_nightly())
        assert c.load_fraction() == 0.0
        Job(name="l", command="true",
            opts=Opts.new(threads=5, memory="1GB", time="10h"),
            sim_duration_s=9999).run(sim)
        assert c.load_fraction() == 0.25
        sim.nodes[1].state = "DOWN"
        assert c.load_fraction() == 0.5  # 5 of the 10 surviving cpus


class TestEngineIntegration:
    def test_deferred_units_held_and_registered(self):
        sim = fresh_sim()
        sched = sched_nightly()
        c = EcoController(sim, sched)
        engine = SubmitEngine(sim, controller=c, now=WED_10, coalesce=False)
        jobs = [eco_job(name=f"e{i}", hours=4) for i in range(3)]
        result = engine.submit_many(jobs)
        assert result.eco_deferred == 3
        assert len(c.held) == 3
        for base in result.base_ids:
            j = sim.get(base)
            assert j.held and not j.begin
        static = sched.next_window(4 * 3600, WED_10)
        sim.advance(to=static.begin)
        for base in result.base_ids:
            assert sim.get(base).started_at <= static.begin

    def test_engine_decisions_match_static_engine(self):
        """Same batch, controller on vs off: identical tiers/deadlines."""
        sched = sched_nightly()
        sim_a, sim_b = fresh_sim(), fresh_sim()
        jobs_a = [eco_job(name=f"a{i}", hours=h) for i, h in enumerate((1, 4, 12))]
        jobs_b = [eco_job(name=f"a{i}", hours=h) for i, h in enumerate((1, 4, 12))]
        SubmitEngine(sim_a, eco=True, scheduler=sched, now=WED_10,
                     coalesce=False).submit_many(jobs_a)
        c = EcoController(sim_b, sched)
        SubmitEngine(sim_b, controller=c, now=WED_10,
                     coalesce=False).submit_many(jobs_b)
        for ja, jb in zip(jobs_a, jobs_b):
            assert ja.eco_meta["tier"] == jb.eco_meta["tier"]
            assert ja.eco_meta["deferred"] == jb.eco_meta["deferred"]
            if ja.opts.begin:
                assert jb.eco_meta["deadline"] == ja.opts.begin


class TestCliAndAdoption:
    def test_runjob_eco_hold_journal_and_adopt(self, capsys):
        from repro.cli import runjob
        from repro.core import get_backend

        rc = runjob.main(["-n", "heldcli", "-t", "2", "--eco", "--eco-hold",
                          "--now", "2026-03-18T10:00:00", "sleep 1"])
        out = capsys.readouterr().out
        assert rc == 0 and "held for favourable load" in out
        sim = get_backend()
        jid = max(j.base_id for j in sim.jobs.values())
        assert sim.get(jid).held
        # a different process (fresh controller) adopts from the journal
        c2 = EcoController.adopt(sim)
        assert str(jid) in c2.held
        assert c2.held[str(jid)].deadline.hour == 0  # the static begin
        sim.advance(to=datetime(2026, 3, 19, 0, 30))
        assert sim.get(jid).state in ("RUNNING", "COMPLETED")

    def test_dry_run_attaches_no_controller(self, capsys):
        from repro.cli import runjob
        from repro.core import get_backend

        rc = runjob.main(["-n", "dryheld", "-t", "2", "--eco", "--eco-hold",
                          "--now", "2026-03-18T10:00:00", "--dry-run",
                          "sleep 1"])
        out = capsys.readouterr().out
        assert rc == 0 and "#SBATCH --hold" in out
        sim = get_backend()
        assert not sim.jobs and not sim.tick_hooks  # nothing leaked

    def test_adopt_skips_manually_held_jobs(self):
        sim = fresh_sim()
        job = eco_job(name="manual")
        job.opts.hold = True  # user hold, no eco journal entry
        job.run(sim)
        c = EcoController.adopt(sim, sched_nightly())
        assert not c.held  # left alone: not ours to release

    def test_waitjobs_eco_release_flag(self, capsys):
        from repro.cli import runjob, waitjobs

        runjob.main(["-n", "wjheld", "-t", "1", "--eco", "--eco-hold",
                     "--now", "2026-03-18T10:00:00", "true"])
        capsys.readouterr()
        rc = waitjobs.main(["-n", "wjheld", "--poll", "3600",
                            "--eco-release", "--quiet"])
        assert rc == 0


class TestNoLaterThanStaticAcceptance:
    def test_simulated_day_releases_never_late(self):
        """Acceptance: across a day of held eco jobs, every release happens
        at or before the job's old static ``--begin``."""
        sim = fresh_sim(nodes=[SimNode(f"n{i}", cpus=64) for i in range(8)])
        sched = sched_with_midday()
        c = EcoController(sim, sched)
        statics = {}
        for i in range(40):
            hours = 1 + (i % 6)
            job = eco_job(name=f"day{i}", hours=hours, duration=300 + i * 30)
            dec = sched.next_window(hours * 3600, WED_10)
            jid = c.submit(job, now=WED_10)
            if dec.deferred:
                statics[str(jid)] = dec.begin
        assert statics, "scenario must actually defer jobs"
        sim.advance(to=WED_10 + timedelta(days=2))
        for jid, static_begin in statics.items():
            j = sim.get(jid)
            assert j.started_at is not None, jid
            assert j.started_at <= static_begin, jid
        for rec in c.released:
            assert rec.at <= rec.deadline
