"""Regression tests for the SQLite-indexed :class:`HistoryStore`.

The sidecar index (``<archive>.idx``) is a pure cache over the JSONL
archive — every test here pins one consequence of that rule: migration
from a pre-existing plain archive, identical answers to the scan path
(including torn/corrupt lines), incremental ingest across appends,
rebuild on rewrite/truncation, graceful fallback, and safety under
concurrent append-while-query.
"""

from __future__ import annotations

import json
import random
import threading
from datetime import datetime, timedelta

import pytest

from repro.accounting import HistoryStore, JobRecord, RuntimePredictor
from repro.accounting.index import HistoryIndex
from repro.accounting.store import SubmitLog


T0 = datetime(2026, 3, 2, 8, 0, 0)

USERS = ["alice", "bob", ""]
STATES = ["COMPLETED", "FAILED", "TIMEOUT", "CANCELLED"]
CLUSTERS = ["", "coal", "wind"]
TOOLS = ["", "kraken2", "blast"]


def make_record(i: int, **kw) -> JobRecord:
    d = dict(
        jobid=str(1000 + i),
        name=f"align-{i}",
        user="alice",
        state="COMPLETED",
        cpus=2,
        runtime_s=600 + i,
        time_limit_s=3600,
        submitted_at=(T0 + timedelta(minutes=i)).isoformat(),
        started_at=(T0 + timedelta(minutes=i, seconds=30)).isoformat(),
        finished_at=(T0 + timedelta(minutes=i + 11)).isoformat(),
    )
    d.update(kw)
    return JobRecord(**d)


def random_records(n: int, seed: int = 0) -> list:
    rng = random.Random(seed)
    out = []
    for i in range(n):
        out.append(
            make_record(
                i,
                user=rng.choice(USERS),
                state=rng.choice(STATES),
                cluster=rng.choice(CLUSTERS),
                tool=rng.choice(TOOLS),
                runtime_s=rng.randrange(0, 7200),
                # some records have no usable timestamps at all
                started_at=""
                if rng.random() < 0.2
                else (T0 + timedelta(minutes=i)).isoformat(),
                submitted_at=""
                if rng.random() < 0.5
                else (T0 + timedelta(minutes=i - 3)).isoformat(),
            )
        )
    return out


def scan_store(path) -> HistoryStore:
    """A store with the index forced off: the reference implementation."""
    s = HistoryStore(path)
    s._index_broken = True
    return s


def dicts(records) -> list:
    return [r.to_dict() for r in records]


# ---------------------------------------------------------------------------
# migration & equivalence
# ---------------------------------------------------------------------------


class TestMigrationAndEquivalence:
    def test_index_builds_from_preexisting_jsonl(self, tmp_path):
        """A plain archive written before the index existed migrates
        transparently: first indexed read ingests the whole file."""
        path = tmp_path / "h.jsonl"
        recs = random_records(50)
        scan_store(path).append_many(recs)
        assert not (tmp_path / "h.jsonl.idx").exists()

        s = HistoryStore(path)
        assert dicts(s.records()) == dicts(recs)
        assert s.ids() == {r.jobid for r in recs}
        assert (tmp_path / "h.jsonl.idx").exists()
        assert s._index_broken is False

    @pytest.mark.parametrize(
        "filters",
        [
            {},
            {"user": "alice"},
            {"user": ""},
            {"state": "COMPLETED"},
            {"cluster": "coal"},
            {"tool": "kraken2"},
            {"tool": "align"},  # name-stem key for untooled records
            {"since": T0 + timedelta(minutes=25)},
            {"user": "bob", "state": "FAILED", "since": T0 + timedelta(minutes=10)},
            {"cluster": "", "tool": "blast"},
        ],
    )
    def test_records_equivalent_to_scan(self, tmp_path, filters):
        path = tmp_path / "h.jsonl"
        HistoryStore(path).append_many(random_records(120, seed=7))
        indexed = HistoryStore(path)
        reference = scan_store(path)
        assert dicts(indexed.records(**filters)) == dicts(
            reference._records_scan(**filters)
        )

    def test_ids_and_len_equivalent(self, tmp_path):
        path = tmp_path / "h.jsonl"
        recs = random_records(40, seed=3)
        HistoryStore(path).append_many(recs)
        indexed, reference = HistoryStore(path), scan_store(path)
        assert indexed.ids() == reference.ids()
        assert len(indexed) == len(reference) == 40

    def test_incremental_ingest_across_appends(self, tmp_path):
        path = tmp_path / "h.jsonl"
        s = HistoryStore(path)
        s.append_many(random_records(10))
        assert len(s.records()) == 10
        idx = s._idx()
        ingested0 = idx.ingested
        s.append_many([make_record(100 + i) for i in range(5)])
        assert len(s.records()) == 15
        # only the appended lines were parsed, and no rebuild happened
        assert idx.ingested == ingested0 + 5
        assert idx.rebuilds == 0

    def test_env_gate_disables_index(self, tmp_path, monkeypatch):
        monkeypatch.setenv("NBI_HISTORY_INDEX", "0")
        path = tmp_path / "h.jsonl"
        s = HistoryStore(path)
        s.append_many(random_records(5))
        assert len(s.records()) == 5
        assert not (tmp_path / "h.jsonl.idx").exists()


# ---------------------------------------------------------------------------
# torn, corrupt, and rewritten archives
# ---------------------------------------------------------------------------


class TestCorruptionTolerance:
    def test_corrupt_and_torn_lines_skipped_like_scan(self, tmp_path):
        path = tmp_path / "h.jsonl"
        good = random_records(6)
        with path.open("w") as fh:
            for i, r in enumerate(good):
                fh.write(json.dumps(r.to_dict()) + "\n")
                if i == 2:
                    fh.write("{this is not json}\n")  # corrupt middle line
            fh.write('{"jobid": "torn", "name": "x", "trunc')  # torn tail
        indexed, reference = HistoryStore(path), scan_store(path)
        assert dicts(indexed.records()) == dicts(reference._records_scan())
        assert indexed.ids() == reference.ids() == {r.jobid for r in good}

    def test_parseable_unterminated_tail_included(self, tmp_path):
        """A valid final line with no newline (crash between write and
        flush) is visible — exactly as the plain scan sees it — without
        being baked into the index."""
        path = tmp_path / "h.jsonl"
        recs = random_records(4)
        HistoryStore(path).append_many(recs)
        with path.open("a") as fh:
            fh.write(json.dumps(make_record(99).to_dict()))  # no newline
        indexed, reference = HistoryStore(path), scan_store(path)
        assert dicts(indexed.records()) == dicts(reference._records_scan())
        assert "1099" in indexed.ids()
        # a later append merges with the tail into one corrupt line; the
        # index must agree with what a fresh scan now sees
        with path.open("a") as fh:
            fh.write(json.dumps(make_record(77).to_dict()) + "\n")
        indexed2, reference2 = HistoryStore(path), scan_store(path)
        assert dicts(indexed2.records()) == dicts(reference2._records_scan())
        assert "1099" not in indexed2.ids()

    def test_rewritten_archive_triggers_rebuild(self, tmp_path):
        path = tmp_path / "h.jsonl"
        s = HistoryStore(path)
        s.append_many(random_records(20))
        assert len(s.records()) == 20
        # rewrite in place (rotation/manual edit): different head bytes
        new = random_records(8, seed=42)
        with path.open("w") as fh:
            for r in new:
                fh.write(json.dumps(r.to_dict(), sort_keys=True) + "\n")
        s2 = HistoryStore(path)
        assert dicts(s2.records()) == dicts(new)
        assert s2.ids() == {r.jobid for r in new}

    def test_truncated_archive_triggers_rebuild(self, tmp_path):
        path = tmp_path / "h.jsonl"
        s = HistoryStore(path)
        recs = random_records(20)
        s.append_many(recs)
        assert len(s.records()) == 20
        keep = path.read_text().splitlines(keepends=True)[:5]
        path.write_text("".join(keep))
        s2 = HistoryStore(path)
        assert dicts(s2.records()) == dicts(recs[:5])

    def test_corrupt_index_file_recovers(self, tmp_path):
        path = tmp_path / "h.jsonl"
        recs = random_records(10)
        s = HistoryStore(path)
        s.append_many(recs)
        assert len(s.records()) == 10
        s._idx().close()
        (tmp_path / "h.jsonl.idx").write_bytes(b"\x00not a sqlite file\x00" * 64)
        s2 = HistoryStore(path)
        assert dicts(s2.records()) == dicts(recs)

    def test_deleting_index_is_safe(self, tmp_path):
        path = tmp_path / "h.jsonl"
        recs = random_records(10)
        s = HistoryStore(path)
        s.append_many(recs)
        assert len(s.records()) == 10
        s._idx().close()
        (tmp_path / "h.jsonl.idx").unlink()
        s2 = HistoryStore(path)
        assert dicts(s2.records()) == dicts(recs)


# ---------------------------------------------------------------------------
# concurrency
# ---------------------------------------------------------------------------


class TestConcurrency:
    def test_append_while_query(self, tmp_path):
        """Writers appending while readers query: no errors, every query
        returns a consistent prefix, and the final state is complete."""
        path = tmp_path / "h.jsonl"
        store = HistoryStore(path)
        store.append_many(random_records(10))
        errors: list = []
        done = threading.Event()

        def writer():
            try:
                for i in range(30):
                    store.append_many([make_record(200 + i, jobid=str(5000 + i))])
            except Exception as e:  # pragma: no cover - failure path
                errors.append(e)
            finally:
                done.set()

        def reader():
            # a separate store instance: its own connection + offsets
            mine = HistoryStore(path)
            try:
                while not done.is_set():
                    n = len(mine.records())
                    assert 10 <= n <= 40
                    ids = mine.ids()
                    assert len(ids) == len(set(ids))
            except Exception as e:  # pragma: no cover - failure path
                errors.append(e)

        threads = [threading.Thread(target=writer)] + [
            threading.Thread(target=reader) for _ in range(2)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errors
        final = HistoryStore(path)
        assert len(final.records()) == 40
        assert final.ids() == scan_store(path).ids()

    def test_ids_cache_avoids_rescan(self, tmp_path, monkeypatch):
        """collect() calls ids() every cycle; between appends it must be
        served from cache, not a fresh archive read."""
        monkeypatch.setenv("NBI_HISTORY_INDEX", "0")
        path = tmp_path / "h.jsonl"
        store = HistoryStore(path)
        store.append_many(random_records(10))
        scans = []
        real_scan = HistoryStore.scan

        def counting_scan(self):
            scans.append(1)
            return real_scan(self)

        monkeypatch.setattr(HistoryStore, "scan", counting_scan)
        first = store.ids()
        assert len(scans) == 1
        second = store.ids()
        assert len(scans) == 1  # served from cache
        assert first == second
        first.add("mutated")  # caller-owned copy: cache unaffected
        assert "mutated" not in store.ids()
        # appends keep the cache warm instead of invalidating it
        store.append_many([make_record(50)])
        assert "1050" in store.ids()
        assert len(scans) == 1
        # an external write (another process) invalidates by size
        with path.open("a") as fh:
            fh.write(json.dumps(make_record(60).to_dict()) + "\n")
        assert "1060" in store.ids()
        assert len(scans) == 2


# ---------------------------------------------------------------------------
# predictor equivalence
# ---------------------------------------------------------------------------


class TestPredictorEquivalence:
    def test_indexed_predictions_match_scan(self, tmp_path, monkeypatch):
        path = tmp_path / "h.jsonl"
        HistoryStore(path).append_many(random_records(150, seed=11))
        indexed = RuntimePredictor(HistoryStore(path))
        reference = RuntimePredictor(scan_store(path))
        for user in USERS + ["nobody"]:
            for key in ["align", "kraken2", "blast", "missing"]:
                for limit in (1800, 12 * 3600):
                    assert indexed.predict(
                        limit, name=key, user=user
                    ) == reference.predict(limit, name=key, user=user), (
                        user,
                        key,
                        limit,
                    )
                assert indexed.sample_count(
                    name=key, user=user
                ) == reference.sample_count(name=key, user=user)
        # the indexed predictor never paid the full-archive build
        assert indexed._index is None
        assert reference._index is not None

    def test_refresh_clears_key_memo(self, tmp_path):
        path = tmp_path / "h.jsonl"
        store = HistoryStore(path)
        store.append_many(
            [make_record(i, name=f"slow-{i}", runtime_s=60) for i in range(5)]
        )
        p = RuntimePredictor(store)
        assert p.predict(7200, name="slow-1") < 7200
        before = p.predict(7200, name="slow-1")
        store.append_many(
            [make_record(50 + i, name=f"slow-{50+i}", runtime_s=7100) for i in range(20)]
        )
        assert p.predict(7200, name="slow-1") == before  # memoized
        p.refresh()
        assert p.predict(7200, name="slow-1") > before


# ---------------------------------------------------------------------------
# submit-log incremental cache
# ---------------------------------------------------------------------------


class TestSubmitLogCache:
    def test_incremental_load_sees_appends(self, tmp_path):
        log = SubmitLog(tmp_path / "h.jsonl.submits")
        log.log_many([("1", "kraken2", None), ("2", "", {"tier": 1, "deferred": True})])
        first = log.load()
        assert set(first) == {"1", "2"}
        log.log_many([("3", "blast", None), ("1", "megahit", None)])
        second = log.load()
        assert set(second) == {"1", "2", "3"}
        assert second["1"]["tool"] == "megahit"  # later entries win

    def test_returned_dicts_are_copies(self, tmp_path):
        log = SubmitLog(tmp_path / "h.jsonl.submits")
        log.log_many([("1", "kraken2", None)])
        a = log.load()
        a["1"]["tool"] = "tampered"
        a["injected"] = {"jobid": "injected"}
        b = log.load()
        assert b["1"]["tool"] == "kraken2"
        assert "injected" not in b

    def test_truncation_resets_cache(self, tmp_path):
        path = tmp_path / "h.jsonl.submits"
        log = SubmitLog(path)
        log.log_many([(str(i), "tool", None) for i in range(10)])
        assert len(log.load()) == 10
        path.write_text("")
        assert log.load() == {}
        log.log_many([("fresh", "tool", None)])
        assert set(log.load()) == {"fresh"}

    def test_missing_file_and_shared_instances(self, tmp_path):
        path = tmp_path / "h.jsonl.submits"
        assert SubmitLog(path).load() == {}
        SubmitLog(path).log_many([("9", "tool", None)])
        # a different instance (fresh HistoryStore) shares the cache by path
        assert set(SubmitLog(path).load()) == {"9"}


# ---------------------------------------------------------------------------
# HistoryIndex internals
# ---------------------------------------------------------------------------


class TestIndexInternals:
    def test_refresh_is_cheap_when_unchanged(self, tmp_path):
        path = tmp_path / "h.jsonl"
        HistoryStore(path).append_many(random_records(10))
        idx = HistoryIndex(path)
        idx.refresh()
        assert idx.ingested == 10
        for _ in range(5):
            idx.refresh()
        assert idx.ingested == 10
        assert idx.rebuilds == 0

    def test_runtimes_for_user_scoping(self, tmp_path):
        path = tmp_path / "h.jsonl"
        HistoryStore(path).append_many(
            [make_record(0, user="alice", runtime_s=100),
             make_record(1, user="alice", runtime_s=300),
             make_record(2, user="bob", runtime_s=200),
             make_record(3, user="bob", state="TIMEOUT", runtime_s=999),
             make_record(4, user="", runtime_s=50)]
        )
        idx = HistoryIndex(path)
        assert idx.runtimes_for("align", "alice") == [100, 300]
        assert idx.runtimes_for("align", "bob") == [200]
        # unknown user falls back to the key-wide list (all completed runs)
        assert idx.runtimes_for("align", "carol") == [50, 100, 200, 300]
        assert idx.runtimes_for("align") == [50, 100, 200, 300]
        assert idx.runtimes_for("missing") == []
