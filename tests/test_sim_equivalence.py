"""Equivalence pin: the event-calendar SimCluster IS the reference model.

``repro.core.simref.ReferenceSimCluster`` keeps the original
sort-everything-per-pass scheduler (O(active) next-event scans, O(pending)
scheduling sweeps). The production ``SimCluster`` replaces those hot paths
with a heap calendar and incremental eligibility sets — and this suite is
what licenses that rewrite: randomized workloads covering submits, arrays,
``--begin``, ``afterok`` chains, holds/releases, cancels, node
failure/restore, timeouts and requeues are driven through BOTH simulators
from identical op scripts, asserting byte-identical

* typed event streams ``(at, type, jobid, state, reason, node)``,
* ``events_log`` transcripts,
* ``queue()`` snapshots at every step,
* final per-job fields (state/reason/node/times/restarts) and energy.

Same idiom as ``tests/test_placer_vectorized.py`` (scalar ``place_spec``
pins vectorized ``place_many``) and ``tests/test_trace_parity.py``.
"""

from __future__ import annotations

import random
from datetime import datetime, timedelta

import pytest

from repro.core.job import Job
from repro.core.resources import Opts
from repro.core.simcluster import SimCluster, SimNode
from repro.core.simref import ReferenceSimCluster

T0 = datetime(2026, 3, 18, 8, 0, 0)

N_SEEDS = 28  # acceptance floor is 25


# ---------------------------------------------------------------------------
# op-script generation: one random program, interpreted on both simulators
# ---------------------------------------------------------------------------


def _gen_job(rng: random.Random, i: int, submitted_ids: list[int], now_s: int):
    """One randomized job spec (as plain data, so both sims build their own)."""
    spec = {
        "name": f"j{i}",
        "cpus": rng.choice([1, 1, 2, 4, 8]),
        "memory": rng.choice(["1GB", "2GB", "4GB"]),
        "time": rng.choice(["10m", "30m", "2h"]),
        "duration": rng.choice([0, 30, 60, 90, 300, 1200, 2400, 7200]),
        "array": rng.choice([0, 0, 0, 0, 2, 3, 5]),
        "hold": rng.random() < 0.12,
        "requeue": rng.random() < 0.8,
        "begin_s": None,
        "deps": [],
    }
    if rng.random() < 0.15:
        spec["begin_s"] = now_s + rng.choice([60, 600, 1800, 3600])
    if submitted_ids and rng.random() < 0.25:
        spec["deps"] = rng.sample(
            submitted_ids, k=min(len(submitted_ids), rng.choice([1, 1, 2]))
        )
    return spec


def gen_script(seed: int) -> list:
    """A random op program: (op, payload) steps with interleaved advances."""
    rng = random.Random(seed)
    ops: list = []
    submitted: list[int] = []  # symbolic ids: index into submissions
    now_s = 0
    n_steps = rng.randint(25, 45)
    for step in range(n_steps):
        r = rng.random()
        if r < 0.45 or not submitted:
            spec = _gen_job(rng, step, submitted, now_s)
            ops.append(("submit", spec))
            submitted.append(len(submitted))
        elif r < 0.55:
            batch = [
                _gen_job(rng, 1000 * step + k, submitted, now_s)
                for k in range(rng.randint(2, 6))
            ]
            ops.append(("submit_many", batch))
            for _ in batch:
                submitted.append(len(submitted))
        elif r < 0.63:
            ops.append(("cancel", rng.sample(submitted, k=1)))
        elif r < 0.71:
            ops.append(("release", rng.sample(submitted, k=1)))
        elif r < 0.76:
            node = f"n{rng.randrange(3):03d}"
            delay = rng.choice([0, 0, 120, 900])
            ops.append(("fail_node", (node, now_s + delay if delay else None)))
        elif r < 0.80:
            ops.append(("restore_node", f"n{rng.randrange(3):03d}"))
        elif r < 0.84:
            ops.append(("wake_at", now_s + rng.choice([30, 45, 300, 300])))
        else:
            dt = rng.choice([0, 15, 60, 61, 300, 1800, 3600])
            now_s += dt
            ops.append(("advance", dt))
    ops.append(("advance", 4 * 3600))
    ops.append(("run_until_idle", 2))
    return ops


# ---------------------------------------------------------------------------
# interpretation
# ---------------------------------------------------------------------------


def make_job(spec: dict, id_map: dict) -> Job:
    opts = Opts.new(
        threads=spec["cpus"], memory=spec["memory"], time=spec["time"]
    )
    if spec["array"]:
        opts.array_size = spec["array"]
    if spec["hold"]:
        opts.hold = True
    opts.requeue = spec["requeue"]
    if spec["begin_s"] is not None:
        opts.begin = (T0 + timedelta(seconds=spec["begin_s"])).isoformat()
    opts.dependencies = [str(id_map[d]) for d in spec["deps"]]
    return Job(
        name=spec["name"], command="true", opts=opts,
        sim_duration_s=spec["duration"],
    )


def fresh_sim(cls):
    nodes = [SimNode(f"n{i:03d}", cpus=8, memory_mb=16384) for i in range(3)]
    return cls(nodes=nodes, now=T0)


def run_script(sim, ops: list) -> list:
    """Interpret an op program; returns queue() snapshots per step."""
    recorded = []
    sim.bus.subscribe(recorded.append)
    id_map: dict[int, int] = {}  # symbolic id -> real base id
    snaps = []
    for op, payload in ops:
        if op == "submit":
            id_map[len(id_map)] = sim.submit(make_job(payload, id_map))
        elif op == "submit_many":
            jobs = []
            base_sym = len(id_map)
            for k, spec in enumerate(payload):
                # deps resolve against ids assigned before this batch
                jobs.append(make_job(spec, id_map))
                id_map[base_sym + k] = None  # placeholder
            ids = sim.submit_many(jobs)
            for k, real in enumerate(ids):
                id_map[base_sym + k] = real
        elif op == "cancel":
            sim.cancel([id_map[s] for s in payload])
        elif op == "release":
            sim.release([id_map[s] for s in payload])
        elif op == "fail_node":
            node, at_s = payload
            at = T0 + timedelta(seconds=at_s) if at_s is not None else None
            try:
                sim.fail_node(node, at=at)
            except KeyError:
                pass  # node name not in this topology variant
        elif op == "restore_node":
            sim.restore_node(payload)
        elif op == "wake_at":
            sim.wake_at(T0 + timedelta(seconds=payload))
        elif op == "advance":
            sim.advance(payload)
        elif op == "run_until_idle":
            sim.run_until_idle(max_days=payload)
        snaps.append(sim.queue())
    return [recorded, snaps]


def event_tuples(events: list) -> list:
    return [
        (e.at, e.type, e.jobid, e.state, e.reason, e.node) for e in events
    ]


def final_fields(sim) -> dict:
    return {
        jid: (
            j.state, j.reason, j.node, j.started_at, j.finished_at,
            j.restarts, j.held, j.energy_j,
        )
        for jid, j in sim.jobs.items()
    }


def assert_equivalent(seed: int) -> None:
    ops = gen_script(seed)
    new = fresh_sim(SimCluster)
    ref = fresh_sim(ReferenceSimCluster)
    new_events, new_snaps = run_script(new, ops)
    ref_events, ref_snaps = run_script(ref, ops)

    assert event_tuples(new_events) == event_tuples(ref_events), (
        f"seed {seed}: event streams diverge"
    )
    assert new.events_log == ref.events_log, f"seed {seed}: events_log"
    assert new_snaps == ref_snaps, f"seed {seed}: queue() snapshots"
    assert new.now == ref.now, f"seed {seed}: final clock"
    assert final_fields(new) == final_fields(ref), f"seed {seed}: job table"
    assert sum(j.energy_j for j in new.jobs.values()) == sum(
        j.energy_j for j in ref.jobs.values()
    ), f"seed {seed}: energy"
    # node occupancy must drain identically too
    assert new.nodes_info() == ref.nodes_info(), f"seed {seed}: nodes"


# ---------------------------------------------------------------------------
# the pin
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_randomized_equivalence(seed):
    assert_equivalent(seed)


class TestDirectedEquivalence:
    """Hand-built corners the random programs may under-sample."""

    def test_zero_duration_burst(self):
        """0-duration jobs finish at the NEXT stop, identically."""
        new, ref = fresh_sim(SimCluster), fresh_sim(ReferenceSimCluster)
        for sim in (new, ref):
            ops = [("submit", {
                "name": f"z{i}", "cpus": 1, "memory": "1GB", "time": "10m",
                "duration": 0, "array": 0, "hold": False, "requeue": True,
                "begin_s": None, "deps": [],
            }) for i in range(8)] + [("advance", 60)]
            run_script(sim, ops)
        assert event_tuples(new.bus.history) == event_tuples(ref.bus.history)
        assert new.events_log == ref.events_log

    def test_dependency_fanout_after_failure(self):
        """A failing dep flips every waiter to DependencyNeverSatisfied at
        the same instant in both simulators."""
        base = {
            "cpus": 1, "memory": "1GB", "time": "10m", "array": 0,
            "hold": False, "requeue": True, "begin_s": None, "deps": [],
        }
        ops = [
            ("submit", dict(base, name="root", duration=7200)),  # blocks node
            ("submit", dict(base, name="victim", duration=900)),
            ("advance", 60),
            ("cancel", [1]),  # victim cancelled -> waiters can never run
        ]
        ops += [
            ("submit", dict(base, name=f"w{i}", duration=60, deps=[1]))
            for i in range(6)
        ]
        ops += [("advance", 3 * 3600), ("run_until_idle", 1)]
        new, ref = fresh_sim(SimCluster), fresh_sim(ReferenceSimCluster)
        new_ev, new_sn = run_script(new, ops)
        ref_ev, ref_sn = run_script(ref, ops)
        assert event_tuples(new_ev) == event_tuples(ref_ev)
        assert new_sn == ref_sn
        assert final_fields(new) == final_fields(ref)
        never = [j for j in new.jobs.values()
                 if j.reason == "DependencyNeverSatisfied"]
        assert len(never) == 6  # the scenario actually exercised the path

    def test_requeue_storm(self):
        """Node churn under load: requeues, restarts and re-placements."""
        base = {
            "cpus": 2, "memory": "2GB", "time": "2h", "array": 0,
            "hold": False, "requeue": True, "begin_s": None, "deps": [],
        }
        ops = [("submit", dict(base, name=f"r{i}", duration=3600))
               for i in range(12)]
        ops += [
            ("advance", 600),
            ("fail_node", ("n000", None)),
            ("advance", 600),
            ("restore_node", "n000"),
            ("advance", 600),
            ("fail_node", ("n001", 2400)),  # scheduled failure
            ("advance", 7200),
            ("restore_node", "n001"),
            ("run_until_idle", 1),
        ]
        new, ref = fresh_sim(SimCluster), fresh_sim(ReferenceSimCluster)
        new_ev, new_sn = run_script(new, ops)
        ref_ev, ref_sn = run_script(ref, ops)
        assert event_tuples(new_ev) == event_tuples(ref_ev)
        assert new.events_log == ref.events_log
        assert new_sn == ref_sn
        assert final_fields(new) == final_fields(ref)
        assert any(j.restarts for j in new.jobs.values())

    def test_timeout_vs_begin_same_instant(self):
        """A timeout and a begin-eligibility landing on one instant order
        identically (failures/completions before scheduling)."""
        base = {
            "cpus": 8, "memory": "8GB", "time": "10m", "array": 0,
            "hold": False, "requeue": True, "deps": [],
        }
        ops = [
            # duration > limit -> TIMEOUT at t=600 on the full node
            ("submit", dict(base, name="hog", duration=7200, begin_s=None)),
            # becomes eligible exactly at t=600, needs the hog's node
            ("submit", dict(base, name="heir", duration=60, begin_s=600)),
            ("advance", 1200),
            ("run_until_idle", 1),
        ]
        new, ref = fresh_sim(SimCluster), fresh_sim(ReferenceSimCluster)
        new_ev, _ = run_script(new, ops)
        ref_ev, _ = run_script(ref, ops)
        assert event_tuples(new_ev) == event_tuples(ref_ev)
        assert new.events_log == ref.events_log
