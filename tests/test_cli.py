"""Command-line tools: runjob, lsjobs, whojobs, waitjobs, session, nbilaunch.

Everything runs against the shared simulator (REPRO_BACKEND=sim from
conftest) — mirroring the paper's "all tests work without Slurm"."""

import json
from pathlib import Path

import pytest

from repro.cli import lsjobs, nbilaunch, runjob, session, waitjobs, whojobs
from repro.core import Queue, get_backend


class TestRunjob:
    def test_paper_assembly_dry_run(self, capsys):
        rc = runjob.main([
            "-n", "assembly", "-c", "18", "-m", "64", "-t", "12",
            "-w", "./logs/", "--dry-run", "--no-eco",
            "flye --nano-raw reads.fastq --out-dir asm",
        ])
        out = capsys.readouterr().out
        assert rc == 0
        assert "#SBATCH --cpus-per-task=18" in out
        assert "#SBATCH --mem=65536" in out  # -m 64 → 64 GB
        assert "#SBATCH --time=0-12:00:00" in out
        assert "flye --nano-raw" in out

    def test_eco_deferral_default_on(self, capsys):
        """Paper: eco is ON by default; Wed 10:00 → --begin next night."""
        rc = runjob.main([
            "-n", "annotate", "-t", "6", "--dry-run",
            "--now", "2026-03-18T10:00:00", "prokka genome.fa",
        ])
        out = capsys.readouterr().out
        assert rc == 0
        assert "#SBATCH --begin=2026-03-19T00:00:00" in out

    def test_no_eco_flag(self, capsys):
        runjob.main(["-n", "x", "--dry-run", "--no-eco",
                     "--now", "2026-03-18T10:00:00", "true"])
        assert "--begin" not in capsys.readouterr().out

    def test_economy_mode_zero_config(self, capsys, tmp_path, monkeypatch):
        from repro.core import write_config

        cfg = tmp_path / "cfg"
        write_config({"economy_mode": "0"}, str(cfg))
        monkeypatch.setenv("NBISLURM_CONFIG", str(cfg))
        runjob.main(["-n", "x", "--dry-run", "--now", "2026-03-18T10:00:00", "true"])
        assert "--begin" not in capsys.readouterr().out

    def test_files_array(self, capsys, tmp_path):
        listing = tmp_path / "samples.txt"
        listing.write_text("a.fq\nb.fq\n")
        runjob.main(["-n", "align", "--files", str(listing), "--dry-run",
                     "--no-eco", "bwa mem ref.fa #FILE# > #FILE#.bam"])
        out = capsys.readouterr().out
        assert "#SBATCH --array=0-1" in out

    def test_submit_to_sim(self, capsys):
        rc = runjob.main(["-n", "real", "--no-eco", "true"])
        out = capsys.readouterr().out
        assert rc == 0
        jid = int(out.strip().splitlines()[-1])
        q = Queue(backend=get_backend())
        assert str(jid) in q.ids()


class TestLsjobs:
    def test_table_and_count(self, capsys):
        runjob.main(["-n", "t1", "--no-eco", "true"])
        runjob.main(["-n", "t2", "--no-eco", "true"])
        capsys.readouterr()
        rc = lsjobs.main(["--all", "--no-color"])
        out = capsys.readouterr().out
        assert rc == 0
        assert "t1" in out and "t2" in out
        assert "2 job(s)" in out

    def test_cancel_with_yes(self, capsys):
        runjob.main(["-n", "doomed", "--no-eco", "sleep 100"])
        capsys.readouterr()
        lsjobs.main(["--all", "-n", "doomed", "--cancel", "--yes"])
        out = capsys.readouterr().out
        assert "cancelled 1 job(s)" in out

    def test_empty_queue(self, capsys):
        lsjobs.main(["--all"])
        assert "no jobs" in capsys.readouterr().out


class TestWhojobs:
    def test_utilisation(self, capsys):
        runjob.main(["-n", "w", "-c", "4", "--no-eco", "true"])
        capsys.readouterr()
        whojobs.main(["--no-color"])
        out = capsys.readouterr().out
        assert "User" in out and "100%" in out


class TestWaitjobs:
    def test_waits_until_done(self, capsys):
        runjob.main(["-n", "waitme", "--no-eco", "true"])
        capsys.readouterr()
        rc = waitjobs.main(["--all" if False else "-n", "waitme", "--poll", "30"])
        out = capsys.readouterr().out
        assert rc == 0
        assert "all jobs finished" in out
        assert len(Queue(name="waitme", backend=get_backend())) == 0

    def test_timeout(self):
        be = get_backend()
        from repro.core import Job, Opts

        Job(name="forever", command="sleep inf",
            opts=Opts.new(threads=1, memory="1GB", time="10h"),
            sim_duration_s=9 * 3600).run(be)
        # tiny sim-time steps so the real-time timeout fires first
        ok = waitjobs.wait_for(be, name="forever", poll_s=0.001, timeout_s=0.05)
        assert not ok


class TestSession:
    def test_print_command(self, capsys):
        rc = session.main(["-c", "8", "-m", "16", "-t", "4", "--print"])
        out = capsys.readouterr().out
        assert rc == 0
        assert "srun --cpus-per-task=8 --mem=16384 --time=0-04:00:00" in out
        assert "--pty bash" in out


class TestNbilaunch:
    def test_list(self, capsys):
        rc = nbilaunch.main(["--list"])
        out = capsys.readouterr().out
        assert rc == 0
        assert "kraken2" in out and "train" in out

    def test_dry_run_train(self, capsys, tmp_path):
        rc = nbilaunch.main([
            "train", "arch=nbi-100m", "steps=5", "--outdir", str(tmp_path),
            "--dry-run", "--no-eco",
        ])
        out = capsys.readouterr().out
        assert rc == 0
        assert "repro.launch.train --arch nbi-100m" in out
        assert "--gres=tpu:v5e:" in out

    def test_submit_writes_manifest(self, capsys, tmp_path):
        rc = nbilaunch.main([
            "train", "arch=nbi-100m", "--outdir", str(tmp_path), "--no-eco",
            "--now", "2026-03-18T10:00:00",
        ])
        assert rc == 0
        rec = json.loads((Path(tmp_path) / "train.manifest.json").read_text())
        assert rec["status"] == "submitted"
        assert rec["inputs"]["arch"] == "nbi-100m"

    def test_unknown_tool(self, capsys):
        assert nbilaunch.main(["nope"]) == 1

    def test_missing_input_reported(self, capsys):
        rc = nbilaunch.main(["kraken2", "--no-eco"])
        out = capsys.readouterr().out
        assert rc == 1
        assert "missing required input" in out


class TestJsonOutput:
    """Satellite: one shared serializer (cli.render.emit_json) behind every
    --json flag, so scripted consumers see a single dialect."""

    def test_lsjobs_json(self, capsys):
        runjob.main(["-n", "jsonjob", "-c", "2", "--no-eco", "sleep 60"])
        capsys.readouterr()
        rc = lsjobs.main(["--all", "--json"])
        out = capsys.readouterr().out
        assert rc == 0
        rows = json.loads(out)
        assert isinstance(rows, list) and rows
        (row,) = [r for r in rows if r["name"] == "jsonjob"]
        assert row["state"] in ("RUNNING", "PENDING")
        assert row["cpus"] == 2  # numeric fields typed, same as whojobs
        assert list(row) == sorted(row)  # shared dialect: sorted keys

    def test_lsjobs_json_respects_filters(self, capsys):
        runjob.main(["-n", "keepme", "--no-eco", "true"])
        runjob.main(["-n", "dropme", "--no-eco", "true"])
        capsys.readouterr()
        lsjobs.main(["--all", "-n", "keepme", "--json"])
        rows = json.loads(capsys.readouterr().out)
        assert {r["name"] for r in rows} == {"keepme"}

    def test_lsjobs_json_empty_queue_is_valid_json(self, capsys):
        lsjobs.main(["--all", "--json"])
        assert json.loads(capsys.readouterr().out) == []

    def test_whojobs_json(self, capsys):
        runjob.main(["-n", "w1", "-c", "4", "--no-eco", "sleep 60"])
        capsys.readouterr()
        rc = whojobs.main(["--json"])
        out = capsys.readouterr().out
        assert rc == 0
        (rec,) = json.loads(out)
        assert rec["cpus"] == 4 and rec["running"] == 1
        assert rec["share"] == 1.0

    def test_whojobs_json_idle_cluster(self, capsys):
        whojobs.main(["--json"])
        assert json.loads(capsys.readouterr().out) == []


class TestRunjobDryRunBegin:
    """Satellite: --dry-run renders the script that WOULD be submitted,
    including the eco-injected --begin, without touching the backend."""

    def test_dry_run_shows_injected_begin_and_submits_nothing(self, capsys):
        rc = runjob.main([
            "-n", "night", "-t", "2", "--dry-run",
            "--now", "2026-03-18T10:00:00", "do_science",
        ])
        captured = capsys.readouterr()
        assert rc == 0
        assert "#SBATCH --begin=2026-03-19T00:00:00" in captured.out
        assert "eco mode: deferred" in captured.err
        assert len(Queue(backend=get_backend())) == 0

    def test_dry_run_batch_array_includes_begin(self, capsys, tmp_path):
        cmds = tmp_path / "cmds.txt"
        cmds.write_text("task one\ntask two\n")
        rc = runjob.main([
            "-n", "batch", "-t", "2", "--from-file", str(cmds), "--array",
            "--dry-run", "--now", "2026-03-18T10:00:00",
        ])
        out = capsys.readouterr().out
        assert rc == 0
        assert "#SBATCH --array=0-1" in out
        assert "#SBATCH --begin=2026-03-19T00:00:00" in out
        assert len(Queue(backend=get_backend())) == 0


class TestEcoreport:
    def _run_some_history(self, tmp_path):
        from datetime import datetime

        from repro.accounting import EnergyModel, HistoryStore, collect
        from repro.core import EcoScheduler, Job, Opts, SimCluster, SubmitEngine

        sim = SimCluster(now=datetime(2026, 3, 18, 10, 0), default_user="alice")
        engine = SubmitEngine(
            sim, eco=True, coalesce=False, now=sim.now,
            scheduler=EcoScheduler(
                weekday_windows=[(0, 360)],
                weekend_windows=[(0, 420), (660, 960)],
                peak_hours=[(1020, 1200)], horizon_days=14, min_delay_s=0,
            ),
        )
        jobs = [Job(name=f"etl-{i}", command="true",
                    opts=Opts.new(threads=2, memory="1GB", time="2h"),
                    sim_duration_s=1800)
                for i in range(8)]
        engine.submit_many(jobs)
        sim.run_until_idle()
        path = tmp_path / "hist.jsonl"
        collect(sim, HistoryStore(path), EnergyModel())
        return path

    def test_table_report(self, capsys, tmp_path):
        from repro.cli import ecoreport

        path = self._run_some_history(tmp_path)
        rc = ecoreport.main(["--history", str(path), "--no-color"])
        out = capsys.readouterr().out
        assert rc == 0
        assert "alice" in out and "Saved(g)" in out
        assert "8 job(s), 8 eco-deferred" in out

    def test_json_report_nonzero_savings(self, capsys, tmp_path):
        from repro.cli import ecoreport

        path = self._run_some_history(tmp_path)
        rc = ecoreport.main(["--history", str(path), "--by", "tool", "--json"])
        payload = json.loads(capsys.readouterr().out)
        assert rc == 0
        tot = payload["total"]
        assert tot["jobs"] == 8
        assert tot["energy_kwh"] > 0
        assert tot["carbon_gco2"] > 0
        assert tot["carbon_saved_gco2"] > 0
        assert payload["groups"][0]["key"] == "etl"

    def test_empty_archive_message(self, capsys, tmp_path):
        from repro.cli import ecoreport

        rc = ecoreport.main(["--history", str(tmp_path / "none.jsonl")])
        assert rc == 0
        assert "no archived jobs" in capsys.readouterr().out

    def test_collect_flag_harvests_shared_sim(self, capsys, tmp_path):
        from repro.cli import ecoreport

        runjob.main(["-n", "harvest", "--no-eco", "true"])
        get_backend().run_until_idle()
        capsys.readouterr()
        path = tmp_path / "hist.jsonl"
        rc = ecoreport.main(["--history", str(path), "--collect"])
        out = capsys.readouterr().out
        assert rc == 0
        assert "collected 1 new record(s)" in out
        rc = ecoreport.main(["--history", str(path), "--collect", "--json"])
        payload = json.loads(capsys.readouterr().out)
        assert payload["total"]["jobs"] == 1

    def test_bad_since_errors(self, capsys, tmp_path):
        from repro.cli import ecoreport

        rc = ecoreport.main(["--history", str(tmp_path / "h.jsonl"),
                             "--since", "not-a-date"])
        assert rc == 2
