"""EcoScheduler: the paper's three-tier window selection + carbon scoring."""

from datetime import datetime, timedelta

import pytest

from repro.core import CarbonTrace, EcoScheduler
from repro.core.config import load_config, write_config

WEEKDAY = [(0, 360)]  # 00:00-06:00
WEEKEND = [(0, 420), (660, 960)]  # 00:00-07:00, 11:00-16:00
PEAK = [(1020, 1200)]  # 17:00-20:00


def make(**kw):
    defaults = dict(
        weekday_windows=WEEKDAY, weekend_windows=WEEKEND, peak_hours=PEAK,
        horizon_days=14, min_delay_s=0,
    )
    defaults.update(kw)
    return EcoScheduler(**defaults)


WED = datetime(2026, 3, 18, 10, 0, 0)  # paper submission day (Wednesday)


class TestPaperExample:
    def test_annotate_six_hours(self):
        """The paper's runjob --eco -t 6: next night window, exactly fits."""
        d = make().next_window(6 * 3600, WED)
        assert d.begin_directive == "2026-03-19T00:00:00"
        assert d.tier == 1
        assert d.deferred

    def test_begin_directive_string(self):
        s = make().begin_directive(6 * 3600, WED)
        assert s == "2026-03-19T00:00:00"


class TestTiers:
    def test_tier1_fits(self):
        d = make().next_window(2 * 3600, WED)
        assert d.tier == 1
        # completes inside 00:00-06:00
        assert d.begin + timedelta(hours=2) <= d.window_end

    def test_tier2_overruns_but_no_peak(self):
        # 10h from 00:00 ends 10:00 — outside the window but before 17:00 peak
        d = make().next_window(10 * 3600, WED)
        assert d.tier == 2
        assert d.begin.hour == 0

    def test_tier3_touches_peak(self):
        # 30h from any eco start inevitably crosses a 17:00-20:00 peak
        d = make().next_window(30 * 3600, WED)
        assert d.tier == 3

    def test_weekend_windows_used(self):
        sat = datetime(2026, 3, 21, 8, 0, 0)  # Saturday 08:00
        d = make().next_window(4 * 3600, sat)
        # next weekend window is Sat 11:00-16:00: 4h fits exactly → tier 1
        assert d.tier == 1
        assert d.begin == datetime(2026, 3, 21, 11, 0, 0)

    def test_inside_window_starts_now(self):
        night = datetime(2026, 3, 18, 1, 0, 0)
        d = make().next_window(3600, night)
        assert d.begin == night
        assert not d.deferred  # already in an eco window → run now

    def test_no_windows_no_deferral(self):
        sched = make(weekday_windows=[], weekend_windows=[])
        d = sched.next_window(3600, WED)
        assert d.tier == 0 and not d.deferred

    def test_min_delay_pushes_start(self):
        sched = make(min_delay_s=7200)
        night = datetime(2026, 3, 18, 1, 0, 0)
        d = sched.next_window(1800, night)
        assert d.begin >= night + timedelta(seconds=7200)


class TestPeakHelpers:
    def test_in_peak(self):
        s = make()
        assert s.in_peak(datetime(2026, 3, 18, 18, 0))
        assert not s.in_peak(datetime(2026, 3, 18, 12, 0))

    def test_in_eco_window(self):
        s = make()
        assert s.in_eco_window(datetime(2026, 3, 18, 3, 0))
        assert not s.in_eco_window(datetime(2026, 3, 18, 12, 0))
        assert s.in_eco_window(datetime(2026, 3, 21, 12, 0))  # weekend midday

    def test_next_peak_start(self):
        s = make()
        assert s.next_peak_start(WED) == datetime(2026, 3, 18, 17, 0)
        # inside the peak → boundary is now
        inside = datetime(2026, 3, 18, 18, 0)
        assert s.next_peak_start(inside) == inside


class TestCarbon:
    def test_trace_lookup(self):
        trace = CarbonTrace([float(i) for i in range(168)])
        assert trace.at(datetime(2026, 3, 16, 0, 0)) == 0  # Monday 00:00
        assert trace.at(datetime(2026, 3, 17, 5, 0)) == 29  # Tuesday 05:00

    def test_carbon_picks_cleanest_same_tier(self):
        hourly = [250.0] * 168
        for d in range(5):
            for h in range(6):
                hourly[d * 24 + h] = 180.0
        for d in (5, 6):  # weekend midday is cleanest
            for h in range(11, 16):
                hourly[d * 24 + h] = 70.0
            for h in range(7):
                hourly[d * 24 + h] = 90.0
        sched = make(carbon_trace=CarbonTrace(hourly))
        d = sched.next_window(4 * 3600, WED)
        assert d.tier == 1
        assert d.begin == datetime(2026, 3, 21, 11, 0)  # Sat midday, 70 g
        assert d.carbon_gco2_kwh == pytest.approx(70.0)

    def test_no_trace_earliest_wins(self):
        d = make().next_window(4 * 3600, WED)
        assert d.begin == datetime(2026, 3, 19, 0, 0)

    def test_trace_from_csv(self, tmp_path):
        p = tmp_path / "trace.csv"
        p.write_text("hour,gco2\n" + "\n".join(f"{i},{100 + i}" for i in range(168)))
        trace = CarbonTrace.from_csv(str(p))
        assert trace.at(datetime(2026, 3, 16, 2, 0)) == 102


class TestConfigFile:
    def test_scheduler_reads_config(self, tmp_path, monkeypatch):
        path = tmp_path / "cfg"
        write_config(
            {
                "eco_weekday_windows": "01:00-05:00",
                "eco_weekend_windows": "",
                "peak_hours": "16:00-21:00",
                "eco_horizon_days": "7",
                "eco_min_delay_minutes": "5",
            },
            str(path),
        )
        monkeypatch.setenv("NBISLURM_CONFIG", str(path))
        sched = EcoScheduler(load_config())
        assert sched.weekday_windows == [(60, 300)]
        assert sched.weekend_windows == []
        assert sched.peak_hours == [(960, 1260)]
        assert sched.horizon_days == 7
        assert sched.min_delay_s == 300

    def test_defaults_match_paper(self):
        cfg = load_config()  # isolated env → pure defaults
        assert cfg.get_windows("eco_weekday_windows") == [(0, 360)]
        assert cfg.get_windows("eco_weekend_windows") == [(0, 420), (660, 960)]
        assert cfg.get_windows("peak_hours") == [(1020, 1200)]
        assert cfg.get_bool("economy_mode") is True  # paper: eco ON by default


class TestWindowParsing:
    """Satellite: overnight / midnight-spanning windows and malformed
    stanza diagnostics in NBIConfig.get_windows / _parse_hhmm."""

    def _cfg(self, **values):
        from repro.core.config import NBIConfig

        return NBIConfig(values=values)

    def test_overnight_window_splits_at_midnight(self):
        cfg = self._cfg(eco_weekday_windows="22:00-06:00")
        assert cfg.get_windows("eco_weekday_windows") == [
            (22 * 60, 24 * 60), (0, 6 * 60),
        ]

    def test_overnight_ending_at_midnight_keeps_one_half(self):
        cfg = self._cfg(eco_weekday_windows="23:30-00:00")
        assert cfg.get_windows("eco_weekday_windows") == [(23 * 60 + 30, 24 * 60)]

    def test_midnight_to_midnight_24h_window_unsplit(self):
        cfg = self._cfg(eco_weekday_windows="00:00-24:00")
        assert cfg.get_windows("eco_weekday_windows") == [(0, 24 * 60)]

    def test_overnight_mixed_with_plain_windows(self):
        cfg = self._cfg(eco_weekday_windows="11:00-13:00,22:00-02:30")
        assert cfg.get_windows("eco_weekday_windows") == [
            (660, 780), (1320, 1440), (0, 150),
        ]

    def test_scheduler_uses_overnight_window(self):
        # a job priced on Wednesday evening lands in the 22:00 half, and a
        # short job fits tier 1 inside the same-night 22:00-24:00 slice
        sched = EcoScheduler(
            self._cfg(
                eco_weekday_windows="22:00-06:00",
                eco_weekend_windows="22:00-06:00",
                peak_hours="",
                eco_horizon_days="3",
                eco_min_delay_minutes="0",
            )
        )
        now = datetime(2026, 3, 18, 10, 0)  # Wednesday morning
        decision = sched.next_window(3600, now)
        assert decision.deferred
        assert decision.begin == datetime(2026, 3, 18, 22, 0)
        assert decision.tier == 1

    def test_malformed_window_no_dash_names_key(self):
        cfg = self._cfg(eco_weekday_windows="10:00")
        with pytest.raises(ValueError) as e:
            cfg.get_windows("eco_weekday_windows")
        assert "eco_weekday_windows" in str(e.value)
        assert "10:00" in str(e.value)
        assert "HH:MM-HH:MM" in str(e.value)

    def test_malformed_window_missing_end(self):
        cfg = self._cfg(peak_hours="17:00-")
        with pytest.raises(ValueError, match="peak_hours"):
            cfg.get_windows("peak_hours")

    def test_malformed_time_of_day_not_numeric(self):
        cfg = self._cfg(peak_hours="aa:bb-cc:dd")
        with pytest.raises(ValueError) as e:
            cfg.get_windows("peak_hours")
        assert "peak_hours" in str(e.value)
        assert "aa:bb" in str(e.value)

    def test_malformed_time_of_day_no_colon(self):
        cfg = self._cfg(peak_hours="1700-2000")
        with pytest.raises(ValueError, match="expected HH:MM"):
            cfg.get_windows("peak_hours")

    def test_time_of_day_out_of_range(self):
        cfg = self._cfg(peak_hours="25:00-26:00")
        with pytest.raises(ValueError, match="out of range"):
            cfg.get_windows("peak_hours")
