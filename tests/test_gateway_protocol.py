"""Gateway wire protocol v2: framing hardening, v1 byte-identity, the
delta protocol, filter pushdown, and bounded event fanout.

Everything here runs against real Unix sockets — raw byte-level clients
where the claim is about bytes (a v1 client must receive frames
byte-identical to the PR-9 daemon's), GatewayClients where the claim is
about semantics (a delta-materialized view must equal a fresh snapshot).
"""

from __future__ import annotations

import json
import socket
import struct
import threading
import time

import pytest

from repro.cli.session import GatewayClient, _QueueView
from repro.core import Job, Opts, SimCluster
from repro.core import gateway as gw
from repro.core.gateway import (
    EMPTY_FILTER_KEY,
    GatewayError,
    GatewayServer,
    canonical_filter_key,
    dumps_wire,
    row_filter,
)

_LEN = struct.Struct(">I")


def _job(name="j", duration=600, **opts):
    return Job(name=name, command="true",
               opts=Opts.new(threads=1, memory="1GB", time="1h", **opts),
               sim_duration_s=duration)


@pytest.fixture
def daemon(tmp_path):
    sim = SimCluster(default_user="alice")
    sock = str(tmp_path / "gw.sock")
    server = GatewayServer(sim, sock, rate=1e6, burst=1e6)
    server.start()
    try:
        yield server, sock, sim
    finally:
        server.close()


def _recv_exact(sock, n):
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return buf
        buf += chunk
    return buf


def _recv_raw_frame(sock) -> bytes:
    """One frame's payload bytes, exactly as they came off the wire."""
    header = _recv_exact(sock, _LEN.size)
    assert len(header) == _LEN.size
    (length,) = _LEN.unpack(header)
    return _recv_exact(sock, length)


def _raw_conn(sock_path) -> socket.socket:
    s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    s.settimeout(10.0)
    s.connect(sock_path)
    return s


def _v1_request(rid, method, params) -> bytes:
    """A request frame exactly as the PR-9 GatewayClient would send it."""
    payload = json.dumps(
        {"id": rid, "method": method, "params": params},
        separators=(",", ":"),
    ).encode("utf-8")
    return _LEN.pack(len(payload)) + payload


class TestCodecHardening:
    def test_dumps_wire_refuses_non_json_values(self):
        from datetime import datetime

        with pytest.raises(GatewayError, match="unserializable"):
            dumps_wire({"at": datetime(2026, 1, 1)})
        with pytest.raises(GatewayError):
            dumps_wire({"s": {1, 2}})
        with pytest.raises(GatewayError):
            dumps_wire(float("nan"))

    def test_oversized_length_prefix_rejected_without_allocation(self, daemon):
        server, sock_path, sim = daemon
        s = _raw_conn(sock_path)
        try:
            # a corrupt 2 GB length prefix: the daemon must answer with a
            # structured error (not allocate, not silently hang up)
            s.sendall(_LEN.pack(2_000_000_000))
            resp = json.loads(_recv_raw_frame(s))
            assert resp["ok"] is False
            assert "frame too large" in resp["error"]
            # ... and then close the unrecoverable stream
            assert s.recv(1) == b""
        finally:
            s.close()

    def test_invalid_json_frame_gets_structured_error(self, daemon):
        server, sock_path, sim = daemon
        s = _raw_conn(sock_path)
        try:
            garbage = b"\xff\xfe not json"
            s.sendall(_LEN.pack(len(garbage)) + garbage)
            resp = json.loads(_recv_raw_frame(s))
            assert resp["ok"] is False and "invalid frame" in resp["error"]
        finally:
            s.close()

    def test_truncated_frame_then_disconnect_leaves_daemon_serving(self, daemon):
        server, sock_path, sim = daemon
        s = _raw_conn(sock_path)
        s.sendall(_LEN.pack(100) + b"only twenty bytes...")  # never finished
        s.close()
        # the daemon shrugged it off and keeps serving everyone else
        assert GatewayClient(sock_path, user="bob").ping()["pong"]

    def test_split_reads_reassemble(self, daemon):
        """A request dribbled in byte-by-byte is still one request."""
        server, sock_path, sim = daemon
        frame = _v1_request(5, "ping", {"user": "alice"})
        s = _raw_conn(sock_path)
        try:
            for i in range(len(frame)):
                s.sendall(frame[i:i + 1])
                time.sleep(0.0005 if i < 8 else 0)
            resp = json.loads(_recv_raw_frame(s))
            assert resp["id"] == 5 and resp["ok"] and resp["result"]["pong"]
        finally:
            s.close()

    def test_pipelined_requests_each_get_a_reply(self, daemon):
        server, sock_path, sim = daemon
        s = _raw_conn(sock_path)
        try:
            s.sendall(_v1_request(1, "ping", {"user": "a"})
                      + _v1_request(2, "queue", {"user": "a"})
                      + _v1_request(3, "ping", {"user": "a"}))
            ids = [json.loads(_recv_raw_frame(s))["id"] for _ in range(3)]
            assert ids == [1, 2, 3]
        finally:
            s.close()


class TestV1ByteIdentity:
    """An old (PR-9) client must not be able to tell the new daemon from
    the old one: same request shape in, byte-identical frames out."""

    def test_queue_frame_bytes_match_v1_encoding(self, daemon):
        server, sock_path, sim = daemon
        GatewayClient(sock_path, user="alice").submit_batch(
            [_job(name=f"b{i}") for i in range(4)], eco=False)
        expected_rows = sim.queue()
        expected = json.dumps(
            {"id": 9, "ok": True, "result": expected_rows},
            separators=(",", ":"),
        ).encode("utf-8")
        s = _raw_conn(sock_path)
        try:
            # the exact v1 request: params carry only the caller user
            s.sendall(_v1_request(9, "queue", {"user": "alice"}))
            payload = _recv_raw_frame(s)
        finally:
            s.close()
        assert payload == expected
        # and the cached-frame fast path (second request) is identical too
        s = _raw_conn(sock_path)
        try:
            s.sendall(_v1_request(9, "queue", {"user": "bob"}))
            assert _recv_raw_frame(s) == expected
        finally:
            s.close()

    def test_v1_client_never_sees_generations(self, daemon):
        server, sock_path, sim = daemon
        s = _raw_conn(sock_path)
        try:
            s.sendall(_v1_request(1, "queue", {"user": "alice"}))
            resp = json.loads(_recv_raw_frame(s))
        finally:
            s.close()
        assert isinstance(resp["result"], list)  # not a v2 wrapper dict


class _V1StubServer:
    """A daemon that predates protocol v2: ignores filters/since and
    answers ``queue`` with the plain full row list."""

    def __init__(self, rows, sock_path):
        self.rows = rows
        self.sock_path = sock_path
        self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._listener.bind(sock_path)
        self._listener.listen(8)
        self._stop = threading.Event()
        threading.Thread(target=self._loop, daemon=True).start()

    def close(self):
        self._stop.set()
        self._listener.close()

    def _loop(self):
        self._listener.settimeout(0.2)
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            try:
                req = gw.recv_frame(conn)
                if req is None:
                    continue
                result = self.rows if req.get("method") == "queue" else {}
                gw.send_frame(conn, {"id": req.get("id"), "ok": True,
                                     "result": result})
            except OSError:
                pass
            finally:
                conn.close()


class TestV2ClientAgainstV1Daemon:
    def test_filters_fall_back_to_local_application(self, tmp_path):
        rows = [
            {"jobid": "1", "user": "alice", "state": "RUNNING", "name": "a"},
            {"jobid": "2", "user": "bob", "state": "PENDING", "name": "b"},
            {"jobid": "3", "user": "alice", "state": "PENDING", "name": "c"},
        ]
        stub = _V1StubServer(rows, str(tmp_path / "v1.sock"))
        try:
            c = GatewayClient(stub.sock_path, user="alice")
            got = c.queue_filtered(user="alice")
            assert [r["jobid"] for r in got] == ["1", "3"]
            assert c._server_v2 is False  # stops sending v2 markers
            assert c.queue_filtered(states=["PENDING"]) == [rows[1], rows[2]]
            assert c.queue() == rows
        finally:
            stub.close()


class TestDeltaProtocol:
    def test_unchanged_short_circuit(self, daemon):
        server, sock_path, sim = daemon
        c = GatewayClient(sock_path, user="alice")
        c.submit_batch([_job(name="x")], eco=False)
        first = c.queue()
        before = server.snapshots.unchanged_hits
        again = c.queue()
        assert again == first
        assert server.snapshots.unchanged_hits == before + 1

    def test_delta_materializes_to_fresh_snapshot(self, daemon):
        server, sock_path, sim = daemon
        c = GatewayClient(sock_path, user="alice")
        c.submit_batch([_job(name=f"j{i}", duration=9000) for i in range(8)],
                       eco=False)
        c.queue()  # view at generation g0
        # one newcomer among 8 survivors: the delta (1 add) is far smaller
        # than the full 9-row snapshot, so the server ships the delta
        c.submit_batch([_job(name="late", duration=9000)], eco=False)
        before = server.snapshots.delta_hits
        via_delta = c.queue()
        assert server.snapshots.delta_hits == before + 1
        fresh = GatewayClient(sock_path, user="alice").queue()
        assert via_delta == fresh  # same rows, same order

    def test_far_behind_client_gets_full_snapshot(self, daemon):
        server, sock_path, sim = daemon
        c = GatewayClient(sock_path, user="alice")
        c.submit_batch([_job(name="seed", duration=30000)], eco=False)
        c.queue()
        # burn through more generations than the encoder's delta history
        for i in range(gw.DELTA_HISTORY + 3):
            c.submit_batch([_job(name=f"g{i}", duration=30000)], eco=False)
            GatewayClient(sock_path, user="alice").queue()  # re-encode each gen
        assert c.queue() == GatewayClient(sock_path, user="alice").queue()

    def test_removals_travel_as_deltas(self, daemon):
        server, sock_path, sim = daemon
        c = GatewayClient(sock_path, user="alice")
        r = c.submit_batch([_job(name=f"d{i}", duration=9000)
                            for i in range(4)], eco=False, coalesce=False)
        assert len(c.queue()) == 4
        c.cancel(r["base_ids"][:1])
        rows = c.queue()
        assert rows == GatewayClient(sock_path, user="alice").queue()
        assert len(rows) == 3

    def test_stale_view_is_resynced_defensively(self, daemon):
        server, sock_path, sim = daemon
        c = GatewayClient(sock_path, user="alice")
        c.submit_batch([_job(name="r", duration=9000)], eco=False)
        c.queue()
        # corrupt the client's view: claim a generation the server never
        # produced — the client must fall back to a full snapshot
        view = c._views[EMPTY_FILTER_KEY]
        view.generation = 999_999
        assert c.queue() == GatewayClient(sock_path, user="alice").queue()


class TestFilterPushdown:
    def test_user_filter_matches_local_filtering(self, daemon):
        server, sock_path, sim = daemon
        alice = GatewayClient(sock_path, user="alice")
        alice.submit_batch([_job(name=f"a{i}", duration=9000)
                            for i in range(3)], eco=False)
        sim.default_user = "bob"
        alice.submit_batch([_job(name="b0", duration=9000)], eco=False)
        sim.default_user = "alice"
        full = alice.queue()
        mine = alice.queue_filtered(user="alice")
        assert mine == [r for r in full if r["user"] == "alice"]
        assert len(mine) == 3

    def test_states_and_ids_filters(self, daemon):
        server, sock_path, sim = daemon
        c = GatewayClient(sock_path, user="alice")
        r = c.submit_batch([_job(name=f"s{i}", duration=9000)
                            for i in range(5)], eco=False)
        running = c.queue_filtered(states=["RUNNING"])
        assert all(row["state"] == "RUNNING" for row in running)
        want = r["base_ids"][0]
        picked = c.queue_filtered(ids=[want])
        assert picked and all(
            row["jobid"] == want or row["jobid"].startswith(f"{want}_")
            for row in picked
        )

    def test_filtered_deltas_stay_consistent(self, daemon):
        server, sock_path, sim = daemon
        c = GatewayClient(sock_path, user="alice")
        c.submit_batch([_job(name=f"f{i}", duration=300) for i in range(4)],
                       eco=False)
        c.queue_filtered(user="alice")
        c.advance(600)  # all four finish
        assert c.queue_filtered(user="alice") == []

    def test_canonical_key_and_row_filter_round_trip(self):
        key = canonical_filter_key(
            {"user": "u", "states": ["running", "PENDING"], "ids": ["7", "7"]}
        )
        assert key == ("u", None, ("7",), ("PENDING", "RUNNING"))
        pred = row_filter(key)
        assert pred({"jobid": "7_3", "user": "u", "state": "RUNNING"})
        assert not pred({"jobid": "8", "user": "u", "state": "RUNNING"})
        assert not pred({"jobid": "7_3", "user": "v", "state": "RUNNING"})
        assert canonical_filter_key({}) == EMPTY_FILTER_KEY
        assert canonical_filter_key(None) == EMPTY_FILTER_KEY


class TestQueueViewOrdering:
    def test_append_rule_matches_server_side_simulation(self):
        view = _QueueView(1, [{"jobid": "1"}, {"jobid": "2"}, {"jobid": "3"}])
        view.apply({"add": [{"jobid": "4"}], "remove": ["2"],
                    "update": [{"jobid": "3", "state": "RUNNING"}]}, None)
        assert view.order == ["1", "3", "4"]
        assert view.by_id["3"]["state"] == "RUNNING"

    def test_explicit_order_wins(self):
        view = _QueueView(1, [{"jobid": "1"}, {"jobid": "2"}])
        view.apply({"add": [{"jobid": "9"}]}, ["9", "2", "1"])
        assert [r["jobid"] for r in view.rows()] == ["9", "2", "1"]

    def test_inconsistent_delta_raises(self):
        view = _QueueView(1, [{"jobid": "1"}])
        with pytest.raises(KeyError):
            view.apply({"update": [{"jobid": "77"}]}, None)
        view = _QueueView(1, [{"jobid": "1"}])
        with pytest.raises(KeyError):
            view.apply({}, ["1", "ghost"])


class TestBoundedEventFanout:
    def test_slow_subscriber_drops_instead_of_blocking(self, daemon,
                                                       monkeypatch):
        server, sock_path, sim = daemon
        monkeypatch.setattr(gw, "EVENT_QUEUE_CAP", 8)
        c = GatewayClient(sock_path, user="alice")
        # keep the simulated queue non-empty for the whole test, or the
        # stream would end itself ("queue drained") before the flood
        c.submit_batch([_job(name="anchor", duration=100_000)], eco=False)
        # subscribe but never read: the subscriber's bounded queue fills
        s = _raw_conn(sock_path)
        try:
            s.sendall(_v1_request(1, "events_subscribe",
                                  {"user": "slow", "duration_s": 60.0,
                                   "poll_s": 0.01}))
            deadline = time.monotonic() + 5.0
            while not server._subs and time.monotonic() < deadline:
                time.sleep(0.01)
            assert server._subs, "subscription never registered"
            # generate far more events than the queue holds; the bus
            # callback (and the submitting client) must never block
            c.submit_batch([_job(name=f"e{i}", duration=60)
                            for i in range(30)], eco=False)
            t0 = time.monotonic()
            c.advance(3600)  # 30 starts + 30 finishes while nobody reads
            assert time.monotonic() - t0 < 5.0
            assert server.events_dropped > 0
        finally:
            s.close()

    def test_subscriber_stream_still_delivers_events(self, daemon):
        server, sock_path, sim = daemon
        c = GatewayClient(sock_path, user="alice")
        c.submit_batch([_job(name="ev1", duration=120),
                        _job(name="ev2", duration=240)],
                       eco=False, coalesce=False)
        # both completions stream out (the starts predate the subscribe)
        got = list(c.events(poll_s=60, duration_s=30, max_events=2))
        assert len(got) == 2
        assert {e.name for e in got} == {"ev1", "ev2"}
        assert all(e.state == "COMPLETED" for e in got)


class TestWorkerBookkeeping:
    def test_wait_workers_are_pruned(self, daemon):
        server, sock_path, sim = daemon
        c = GatewayClient(sock_path, user="alice")
        for i in range(3):
            r = c.submit_batch([_job(name=f"w{i}", duration=60)], eco=False)
            out = c.wait(ids=r["base_ids"], poll_s=600)
            assert out["ok"]
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            c.ping()  # each pass lets the serve loop prune dead workers
            if not server._workers:
                break
            time.sleep(0.05)
        assert server._workers == []
