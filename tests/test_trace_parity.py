"""Trace parity pin: the PollingEventAdapter produces the same span
timelines as the simulator's native bus.

One SimCluster, two observers: a JobTracer on the native bus (events at
the exact simulated instant) and a JobTracer on a PollingEventAdapter
that polls the same cluster at every simulated boundary with
``now=sim.now``. Because every transition in this workload lands on a
poll boundary, the adapter must reconstruct byte-identical
``(event type, at)`` timelines — which is what makes JobTracer (and
every other bus consumer) backend-agnostic.
"""

from repro.core import PollingEventAdapter
from repro.core import events as ev
from repro.core.job import Job
from repro.core.resources import Opts
from repro.obs.trace import JobTracer


def make_job(name="j", *, duration=60, cpus=1):
    opts = Opts.new(threads=cpus, memory="1GB", time="1h")
    return Job(name=name, command="true", opts=opts, sim_duration_s=duration)


def timelines(tracer: JobTracer) -> dict:
    spans = list(tracer.recent) + list(tracer.open.values())
    return {s.jobid: s.timeline for s in spans}


class TestAdapterParity:
    def test_identical_span_timelines(self, sim):
        native = JobTracer().attach(sim.bus)
        adapter = PollingEventAdapter(sim)
        polled = JobTracer().attach(adapter.bus)

        adapter.poll(now=sim.now)  # baseline: empty queue, no events
        jids = [str(make_job(name=f"j{i}", duration=60 * (i + 1)).run(sim))
                for i in range(3)]
        adapter.poll(now=sim.now)  # submissions (and immediate starts)
        for _ in range(10):
            sim.advance(60)
            adapter.poll(now=sim.now)

        native.detach()
        polled.detach()
        assert native.finished == polled.finished == 3
        nat, pol = timelines(native), timelines(polled)
        assert set(nat) == set(pol) == set(jids)
        for jid in jids:
            assert nat[jid] == pol[jid]  # same types, same instants

    def test_cancelled_job_parity(self, sim):
        native = JobTracer().attach(sim.bus)
        adapter = PollingEventAdapter(sim)
        polled = JobTracer().attach(adapter.bus)

        adapter.poll(now=sim.now)
        jid = str(make_job(duration=3600).run(sim))
        adapter.poll(now=sim.now)
        sim.advance(60)
        adapter.poll(now=sim.now)
        sim.cancel([jid])
        adapter.poll(now=sim.now)

        native.detach()
        polled.detach()
        nat, pol = timelines(native), timelines(polled)
        assert nat[jid] == pol[jid]
        assert nat[jid][-1][0] == ev.CANCELLED

    def test_derived_durations_agree(self, sim):
        """Parity extends to the metrics the spans derive."""
        native = JobTracer().attach(sim.bus)
        adapter = PollingEventAdapter(sim)
        polled = JobTracer().attach(adapter.bus)

        adapter.poll(now=sim.now)
        jid = str(make_job(duration=120).run(sim))
        adapter.poll(now=sim.now)
        for _ in range(4):
            sim.advance(60)
            adapter.poll(now=sim.now)

        native.detach()
        polled.detach()
        n = next(s for s in native.recent if s.jobid == jid)
        p = next(s for s in polled.recent if s.jobid == jid)
        assert (n.queue_wait_s, n.lifetime_s, n.outcome) == \
            (p.queue_wait_s, p.lifetime_s, p.outcome)
