"""Gateway daemon: protocol round-trips, namespacing, fair share, the
thin-client fallback, and the shared-cache detach regression.

Everything runs the real Unix-socket path — a GatewayServer in a daemon
thread over a dedicated simulator, GatewayClients connecting through the
filesystem — so the frames, threading and lifecycle under test are
exactly what production ``nbid`` runs.
"""

from __future__ import annotations

import json
import threading

import pytest

from repro.cli.session import GatewayClient, resolve_backend
from repro.core import Job, Opts, SimCluster, get_backend, get_queue_cache
from repro.core.engine import QueueCache
from repro.core.gateway import (
    GatewayConnectionLost,
    GatewayError,
    GatewayServer,
    TokenBucket,
    job_from_wire,
    job_to_wire,
)


def _job(name="j", duration=60, **opts):
    return Job(name=name, command="true",
               opts=Opts.new(threads=1, memory="1GB", time="1h", **opts),
               sim_duration_s=duration)


@pytest.fixture
def daemon(tmp_path):
    """A served gateway over a dedicated simulator; closed after the test."""
    sim = SimCluster(default_user="alice")
    sock = str(tmp_path / "gw.sock")
    server = GatewayServer(sim, sock, rate=10_000, burst=10_000)
    server.start()
    try:
        yield server, sock, sim
    finally:
        server.close()


def _client(sock, user="alice"):
    return GatewayClient(sock, user=user)


class TestTokenBucket:
    def test_burst_then_linear_delay(self):
        now = [100.0]
        b = TokenBucket(rate=10.0, burst=5.0, clock=lambda: now[0])
        assert [b.reserve() for _ in range(5)] == [0.0] * 5
        assert b.reserve() == pytest.approx(0.1)
        assert b.reserve() == pytest.approx(0.2)

    def test_refill_restores_credit(self):
        now = [0.0]
        b = TokenBucket(rate=10.0, burst=2.0, clock=lambda: now[0])
        b.reserve(), b.reserve()
        assert b.reserve() > 0
        now[0] += 10.0  # long idle: bucket refills to burst, no further
        assert b.reserve() == 0.0
        assert b.reserve() == 0.0
        assert b.reserve() > 0.0


class TestWireFormat:
    def test_job_round_trip_through_json(self):
        job = _job(name="wire", duration=120, queue="short")
        job.prelude = ["module load x"]
        job.files = ["a.fastq", "b.fastq"]
        wire = json.loads(json.dumps(job_to_wire(job)))
        back = job_from_wire(wire)
        assert back.name == "wire"
        assert back.files == ["a.fastq", "b.fastq"]
        assert back.prelude == ["module load x"]
        assert back.sim_duration_s == 120
        assert back.opts.queue == "short"
        assert back.opts.threads == job.opts.threads
        assert back.opts.memory_mb == job.opts.memory_mb

    def test_unknown_opts_keys_dropped(self):
        wire = job_to_wire(_job())
        wire["opts"]["knob_from_the_future"] = 7
        assert job_from_wire(wire).opts.threads == 1


class TestServerRpc:
    def test_ping_and_empty_queue(self, daemon):
        server, sock, sim = daemon
        c = _client(sock)
        pong = c.ping()
        assert pong["pong"] and pong["backend"] == "SimCluster"
        assert c.queue() == []
        assert c.nodes_info()[0]["name"] == "n000"

    def test_submit_batch_coalesces_and_runs_to_completion(self, daemon):
        server, sock, sim = daemon
        c = _client(sock)
        r = c.submit_batch([_job(name="sweep") for _ in range(6)], eco=False)
        assert r["sbatch_calls"] == 1 and r["coalesced"] == 6
        assert len(r["ids"]) == 6
        assert len(c.queue()) == 6
        c.advance(3600)
        assert c.queue() == []
        states = {j.state for j in sim.jobs.values()}
        assert states == {"COMPLETED"}

    def test_wait_rpc_drains_and_reports_states(self, daemon):
        server, sock, sim = daemon
        c = _client(sock)
        r = c.submit_batch([_job(name="w", duration=300)], eco=False)
        out = c.wait(ids=r["base_ids"], poll_s=600)
        assert out["ok"]
        assert set(out["states"].values()) == {"COMPLETED"}

    def test_cancel_is_namespaced_per_user(self, daemon):
        server, sock, sim = daemon
        alice, bob = _client(sock, "alice"), _client(sock, "bob")
        rid = alice.submit_batch([_job(name="mine", duration=9000)],
                                 eco=False)["base_ids"][0]
        denied = bob._call("cancel", ids=[rid])
        assert denied == {"cancelled": [], "denied": [rid]}
        assert len(alice.queue()) == 1  # still running: bob couldn't touch it
        ok = alice._call("cancel", ids=[rid])
        assert ok["cancelled"] == [rid] and ok["denied"] == []
        assert alice.queue() == []

    def test_unknown_ids_pass_through_namespacing(self, daemon):
        server, sock, sim = daemon
        bob = _client(sock, "bob")
        # the daemon never saw this id — it cannot know the owner, so the
        # request is forwarded rather than denied
        out = bob._call("cancel", ids=["424242"])
        assert out == {"cancelled": ["424242"], "denied": []}

    def test_unknown_method_is_a_gateway_error(self, daemon):
        server, sock, sim = daemon
        with pytest.raises(GatewayError, match="unknown method"):
            _client(sock)._call("frobnicate")

    def test_events_stream_honours_max_events(self, daemon):
        server, sock, sim = daemon
        c = _client(sock)
        c.submit_batch([_job(name=f"e{i}", duration=60 * (i + 1))
                        for i in range(4)], eco=False, coalesce=False)
        events = list(c.events(poll_s=120, max_events=3))
        assert len(events) == 3
        assert all(e.jobid for e in events)

    def test_stats_counts_requests_and_cache_traffic(self, daemon):
        server, sock, sim = daemon
        c = _client(sock)
        for _ in range(5):
            c.queue()
        s = c.stats()
        assert s["daemon"]["requests"]["queue"] == 5
        assert s["daemon"]["backend"] == "SimCluster"
        qc = s["queue_cache"]
        # one poll filled the snapshot; every request after that was
        # served from the encoder's pre-framed bytes without touching
        # the cache at all (v2: repeats collapse to "unchanged")
        assert qc["polls"] == 1
        snap = s["snapshot"]
        assert snap["refreshes"] == 1
        assert snap["unchanged_hits"] >= 4  # delta protocol kicked in
        assert "eco" in s

    def test_throttle_counts_over_budget_users(self, tmp_path):
        sim = SimCluster()
        sock = str(tmp_path / "tb.sock")
        server = GatewayServer(sim, sock, rate=1000.0, burst=1.0,
                               max_throttle_s=0.0)
        server.start()
        try:
            c = _client(sock, "flood")
            for _ in range(4):
                c.ping()
            assert server.throttled >= 2  # burst of 1: back-to-back pings owe
        finally:
            server.close()


class TestServerLifecycle:
    def test_close_unlinks_socket_and_refuses_clients(self, tmp_path):
        sim = SimCluster()
        sock = str(tmp_path / "gone.sock")
        server = GatewayServer(sim, sock, rate=1000, burst=1000)
        server.start()
        _client(sock).ping()
        server.close()
        import os

        assert not os.path.exists(sock)
        with pytest.raises(ConnectionError):
            _client(sock).ping()

    def test_close_leaves_no_stale_bus_subscribers(self, tmp_path):
        sim = SimCluster()
        baseline = len(sim.bus._subs)
        server = GatewayServer(sim, str(tmp_path / "s.sock"))
        server.start()
        _client(server.socket_path).ping()
        assert len(sim.bus._subs) > baseline  # the daemon's cache is bound
        server.close()
        assert len(sim.bus._subs) == baseline

    def test_second_daemon_on_a_live_socket_refuses(self, daemon):
        server, sock, sim = daemon
        rival = GatewayServer(SimCluster(), sock)
        with pytest.raises(GatewayError, match="another gateway is live"):
            rival.bind()

    def test_stale_socket_file_is_reclaimed(self, tmp_path):
        sock = str(tmp_path / "stale.sock")
        first = GatewayServer(SimCluster(), sock)
        first.bind()
        # simulate a crash: drop the listener without unlinking the path
        first._listener.close()
        first._listener = None
        second = GatewayServer(SimCluster(), sock, rate=1000, burst=1000)
        second.start()
        try:
            assert _client(sock).ping()["pong"]
        finally:
            second.close()

    def test_shutdown_rpc_stops_the_server(self, tmp_path):
        server = GatewayServer(SimCluster(), str(tmp_path / "x.sock"),
                               rate=1000, burst=1000)
        thread = server.start()
        _client(server.socket_path).shutdown()
        thread.join(timeout=5.0)
        assert not thread.is_alive()
        server.close()


class TestResolveBackend:
    def test_no_daemon_falls_back_to_shared_cache(self, tmp_path):
        backend = resolve_backend(None, str(tmp_path / "absent.sock"))
        assert isinstance(backend, QueueCache)
        assert backend is get_queue_cache()

    def test_gateway_required_raises_without_daemon(self, tmp_path):
        with pytest.raises(GatewayConnectionLost):
            resolve_backend(True, str(tmp_path / "absent.sock"))

    def test_gateway_false_ignores_a_live_daemon(self, daemon):
        server, sock, sim = daemon
        assert isinstance(resolve_backend(False, sock), QueueCache)

    def test_auto_detect_prefers_a_live_daemon(self, daemon, monkeypatch):
        server, sock, sim = daemon
        monkeypatch.setenv("NBI_GATEWAY_SOCKET", sock)
        backend = resolve_backend(None, None)
        assert isinstance(backend, GatewayClient)

    def test_nbi_no_gateway_env_forces_in_process(self, daemon, monkeypatch):
        server, sock, sim = daemon
        monkeypatch.setenv("NBI_GATEWAY_SOCKET", sock)
        monkeypatch.setenv("NBI_NO_GATEWAY", "1")
        assert isinstance(resolve_backend(None, None), QueueCache)


class TestCliEquivalence:
    """The acceptance criterion: the no-daemon path is byte-identical, and
    a live daemon serves the same rows the in-process path would."""

    def _submit_shared(self, n=3):
        from repro.core.engine import SubmitEngine

        engine = SubmitEngine(get_queue_cache())
        return engine.submit_many(
            [_job(name=f"eq{i}", duration=7200) for i in range(n)]
        )

    def test_fallback_json_identical_to_no_gateway(self, tmp_path, capsys,
                                                   monkeypatch):
        from repro.cli import lsjobs

        monkeypatch.setenv("NBI_GATEWAY_SOCKET", str(tmp_path / "none.sock"))
        self._submit_shared()
        assert lsjobs.main(["--all", "--json"]) == 0
        auto = capsys.readouterr().out
        assert lsjobs.main(["--all", "--json", "--no-gateway"]) == 0
        forced = capsys.readouterr().out
        assert auto == forced
        assert len(json.loads(auto)) == 3

    def test_daemon_serves_the_same_rows_as_in_process(self, tmp_path,
                                                       capsys):
        from repro.cli import lsjobs

        self._submit_shared()
        server = GatewayServer(get_backend(), str(tmp_path / "eq.sock"),
                               rate=1000, burst=1000)
        server.start()
        try:
            assert lsjobs.main(["--all", "--json", "--no-gateway"]) == 0
            local = json.loads(capsys.readouterr().out)
            assert lsjobs.main(["--all", "--json", "--gateway",
                                "--gateway-socket", server.socket_path]) == 0
            via_daemon = json.loads(capsys.readouterr().out)
        finally:
            server.close()
        assert via_daemon == local

    def test_runjob_submits_through_the_daemon(self, daemon, capsys):
        from repro.cli import runjob

        server, sock, sim = daemon
        rc = runjob.main(["-n", "gwjob", "--no-eco", "--gateway",
                          "--gateway-socket", sock, "echo hi"])
        assert rc == 0
        assert any(j.name == "gwjob" for j in sim.jobs.values())
        # the shared in-process simulator never saw it: daemon-side submit
        assert all(j.name != "gwjob" for j in
                   getattr(get_backend(), "jobs", {}).values())


class TestWaitjobsExitCodes:
    def test_connection_refused_exits_3(self, tmp_path, capsys):
        from repro.cli import waitjobs

        rc = waitjobs.main(["--gateway",
                            "--gateway-socket", str(tmp_path / "no.sock")])
        assert rc == 3
        assert "gateway connection failed" in capsys.readouterr().err

    def test_connection_lost_mid_wait_exits_3(self, daemon, capsys,
                                              monkeypatch):
        from repro.cli import waitjobs

        server, sock, sim = daemon
        _client(sock).submit_batch([_job(duration=9000)], eco=False)

        def lost(self, **kw):
            raise GatewayConnectionLost("daemon died mid-wait")

        monkeypatch.setattr(GatewayClient, "wait", lost)
        rc = waitjobs.main(["--gateway", "--gateway-socket", sock, "--json"])
        out = json.loads(capsys.readouterr().out)
        assert rc == 3
        assert out["connection_lost"] is True
        assert out["timed_out"] is False

    def test_native_wait_loop_connection_error_exits_3(self):
        from repro.cli.waitjobs import wait_for_events

        class DyingSim(SimCluster):
            def advance(self, seconds):
                raise ConnectionError("backend went away")

        sim = DyingSim(default_user="alice")
        jid = _job(duration=9000).run(sim)
        result = wait_for_events(sim, ids=[jid], poll_s=60)
        assert result.connection_lost and not result.ok
        assert result.exit_code == 3
        d = result.to_dict()
        assert d["connection_lost"] is True and d["timed_out"] is False

    def test_timeout_still_exits_2_not_3(self, daemon):
        server, sock, sim = daemon
        c = _client(sock)
        slow = Job(name="slow", command="true",
                   opts=Opts.new(threads=1, memory="1GB", time="9000h"),
                   sim_duration_s=10_000_000)
        r = c.submit_batch([slow], eco=False)
        out = c.wait(ids=r["base_ids"], poll_s=60, timeout_s=0.2)
        assert out["ok"] is False  # the daemon observed it but it was slow
        from repro.cli.waitjobs import WaitResult

        assert WaitResult(ok=False).exit_code == 2


class TestSharedCacheDetach:
    """Satellite regression: dropping a shared backend must unbind the
    shared QueueCache from its bus first — no stale subscribers."""

    def test_reset_queue_cache_unbinds_the_bus(self):
        sim = get_backend()
        baseline = len(sim.bus._subs)
        cache = get_queue_cache()
        assert len(sim.bus._subs) == baseline + 1
        from repro.core import reset_queue_cache

        reset_queue_cache()
        assert len(sim.bus._subs) == baseline
        assert cache._bus_token is None

    def test_reset_backend_is_the_public_alias(self):
        from repro.core import reset_backend

        first = get_backend()
        get_queue_cache()
        reset_backend()
        assert get_backend() is not first

    def test_federation_rebuild_detaches_the_shared_cache(self, tmp_path,
                                                          monkeypatch):
        cfg = tmp_path / "fed.config"
        cfg.write_text("[cluster.a]\nkind=sim\n[cluster.b]\nkind=sim\n")
        monkeypatch.setenv("NBISLURM_CONFIG", str(cfg))
        monkeypatch.setenv("REPRO_BACKEND", "federated")
        from repro.core import reset_backend

        reset_backend()
        fed = get_backend()
        old_bus = fed.bus
        before_bind = len(old_bus._subs)
        cache = get_queue_cache()
        assert cache.inner is fed
        assert len(old_bus._subs) == before_bind + 1
        # config change → the shared federation is rebuilt; the outgoing
        # bus must shed the cache's subscription as part of the teardown
        cfg.write_text("[cluster.a]\nkind=sim\nnodes=2\n[cluster.b]\nkind=sim\n")
        rebuilt = get_backend()
        assert rebuilt is not fed
        # the cache's subscription is gone (fed.close() also drops the
        # federation's own internal subscribers, hence <=, not ==)
        assert cache._bus_token is None
        assert len(old_bus._subs) <= before_bind
        reset_backend()


class TestNbimonGateway:
    def test_live_streams_the_daemon_ticker(self, daemon, capsys):
        from repro.cli import nbimon

        server, sock, sim = daemon
        _client(sock).submit_batch(
            [_job(name=f"mon{i}", duration=60 * (i + 1)) for i in range(3)],
            eco=False, coalesce=False,
        )
        rc = nbimon.main(["--live", "--poll", "120", "--json",
                          "--gateway", "--gateway-socket", sock])
        captured = capsys.readouterr()
        assert rc == 0
        payload = json.loads(captured.out)
        assert payload["events_streamed"] > 0
        assert payload["daemon"]["backend"] == "SimCluster"
        # ticker lines went to stderr so stdout stayed machine-readable
        assert "COMPLETED" in captured.err

    def test_scrape_renders_daemon_counters(self, daemon, capsys):
        from repro.cli import nbimon
        from repro.obs.metrics import disable

        disable()  # an enabled registry switches the scrape to Prometheus text
        server, sock, sim = daemon
        _client(sock).queue()
        rc = nbimon.main(["--gateway", "--gateway-socket", sock])
        out = capsys.readouterr().out
        assert rc == 0
        assert "gateway pid" in out and "poll(s)" in out


class TestNbidCli:
    def test_status_and_stop(self, daemon, capsys):
        from repro.cli import nbid

        server, sock, sim = daemon
        assert nbid.main(["--status", "--socket", sock]) == 0
        assert "nbid pid" in capsys.readouterr().out
        assert nbid.main(["--status", "--json", "--socket", sock]) == 0
        assert json.loads(capsys.readouterr().out)["daemon"]["socket"] == sock
        assert nbid.main(["--stop", "--socket", sock]) == 0
        server._stop.wait(5.0)
        assert server._stop.is_set()

    def test_status_without_daemon_fails(self, tmp_path, capsys):
        from repro.cli import nbid

        rc = nbid.main(["--status", "--socket", str(tmp_path / "no.sock")])
        assert rc == 1
        assert "nbid:" in capsys.readouterr().err
