"""SimCluster — the deterministic SLURM model: scheduling, --begin,
dependencies, timeouts, node failure + requeue (fault-tolerance drills)."""

from datetime import datetime, timedelta

from repro.core import Job, Opts, SimCluster, SimNode


def mkjob(name="j", duration=60, begin="", deps=None, cpus=2, time="1h",
          requeue=True):
    opts = Opts.new(threads=cpus, memory="1GB", time=time)
    if begin:
        opts.set_begin(begin)
    if deps:
        opts.dependencies = deps
    opts.requeue = requeue
    return Job(name=name, command="true", opts=opts, sim_duration_s=duration)


class TestScheduling:
    def test_fifo_start_and_finish(self, sim):
        jid = mkjob(duration=120).run(sim)
        assert sim.get(jid).state == "RUNNING"
        sim.advance(119)
        assert sim.get(jid).state == "RUNNING"
        sim.advance(2)
        assert sim.get(jid).state == "COMPLETED"

    def test_resources_block(self):
        sim = SimCluster(nodes=[SimNode("n0", cpus=4)])
        a = mkjob("a", cpus=3, duration=100).run(sim)
        b = mkjob("b", cpus=3, duration=100).run(sim)
        assert sim.get(a).state == "RUNNING"
        assert sim.get(b).state == "PENDING"
        assert sim.get(b).reason == "Resources"
        sim.advance(101)
        assert sim.get(b).state == "RUNNING"

    def test_timeout(self, sim):
        jid = mkjob(duration=7200, time="1h").run(sim)
        sim.run_until_idle()
        assert sim.get(jid).state == "TIMEOUT"


class TestBegin:
    def test_begin_defers(self, sim):
        begin = (sim.now + timedelta(hours=2)).isoformat()
        jid = mkjob(begin=begin, duration=60).run(sim)
        assert sim.get(jid).state == "PENDING"
        assert sim.get(jid).reason == "BeginTime"
        sim.advance(2 * 3600 - 60)
        assert sim.get(jid).state == "PENDING"
        sim.advance(61)
        assert sim.get(jid).state == "RUNNING"

    def test_eco_begin_integration(self, sim):
        """A --begin injected by the eco scheduler starts at the window."""
        from repro.core import EcoScheduler

        sched = EcoScheduler(weekday_windows=[(0, 360)], weekend_windows=[],
                             peak_hours=[], horizon_days=7, min_delay_s=0)
        d = sched.next_window(3600, sim.now)
        jid = mkjob(begin=d.begin_directive, duration=600).run(sim)
        sim.advance(to=d.begin - timedelta(seconds=1))
        assert sim.get(jid).state == "PENDING"
        sim.advance(2)
        assert sim.get(jid).state == "RUNNING"


class TestDependencies:
    def test_afterok_chain(self, sim):
        a = mkjob("a", duration=60).run(sim)
        b = mkjob("b", duration=60, deps=[a]).run(sim)
        assert sim.get(b).reason == "Dependency"
        sim.advance(61)
        assert sim.get(b).state == "RUNNING"
        sim.run_until_idle()
        assert sim.get(b).state == "COMPLETED"

    def test_dependency_never_satisfied(self, sim):
        a = mkjob("a", duration=7200, time="1h").run(sim)  # will TIMEOUT
        b = mkjob("b", deps=[a]).run(sim)
        sim.run_until_idle()
        assert sim.get(a).state == "TIMEOUT"
        assert sim.get(b).state == "PENDING"
        assert sim.get(b).reason == "DependencyNeverSatisfied"


class TestNodeFailure:
    def test_requeue_on_node_failure(self, sim):
        jid = mkjob(duration=600).run(sim)
        node = sim.get(jid).node
        sim.advance(60)
        sim.fail_node(node)
        j = sim.get(jid)
        # requeued → rescheduled (possibly instantly on another UP node)
        assert j.restarts == 1
        assert j.state in ("PENDING", "RUNNING")
        assert j.node != node or j.state == "PENDING"
        sim.run_until_idle()
        assert sim.get(jid).state == "COMPLETED"

    def test_no_requeue_fails(self, sim):
        jid = mkjob(duration=600, requeue=False).run(sim)
        sim.fail_node(sim.get(jid).node)
        assert sim.get(jid).state == "NODE_FAIL"

    def test_scheduled_failure_and_restore(self):
        sim = SimCluster(nodes=[SimNode("n0", cpus=4)])
        jid = mkjob(duration=600, cpus=4).run(sim)
        sim.fail_node("n0", at=sim.now + timedelta(seconds=60))
        sim.advance(120)
        j = sim.get(jid)
        assert j.state == "PENDING"  # only node is down
        sim.restore_node("n0")
        assert sim.get(jid).state == "RUNNING"
        sim.run_until_idle()
        assert sim.get(jid).state == "COMPLETED"

    def test_capacity_drain_many_failures(self):
        """1000-node style drill: kill 30% of nodes mid-run; every requeueable
        job still completes."""
        sim = SimCluster(nodes=[SimNode(f"n{i:03d}", cpus=8) for i in range(20)])
        ids = [mkjob(f"j{i}", duration=600, cpus=4).run(sim) for i in range(30)]
        sim.advance(60)
        for i in range(6):
            sim.fail_node(f"n{i:03d}")
        sim.run_until_idle()
        states = {jid: sim.get(jid).state for jid in ids}
        assert set(states.values()) == {"COMPLETED"}


class TestExecution:
    def test_execute_runs_script(self, exec_sim, tmp_path, monkeypatch):
        monkeypatch.setenv("NBI_TMPDIR", str(tmp_path))
        marker = tmp_path / "ran.txt"
        job = Job(name="x", command=f"echo done > {marker}",
                  opts=Opts.new(threads=1, memory="1GB", time="1h"),
                  sim_duration_s=10)
        job.run(exec_sim)
        exec_sim.run_until_idle()
        assert marker.read_text().strip() == "done"

    def test_failed_script_reported(self, exec_sim, tmp_path, monkeypatch):
        monkeypatch.setenv("NBI_TMPDIR", str(tmp_path))
        job = Job(name="bad", command="exit 3",
                  opts=Opts.new(threads=1, memory="1GB", time="1h"),
                  sim_duration_s=10)
        jid = job.run(exec_sim)
        exec_sim.run_until_idle()
        j = exec_sim.get(jid)
        assert j.state == "FAILED"
        assert "3" in j.reason

    def test_array_env_vars(self, exec_sim, tmp_path, monkeypatch):
        monkeypatch.setenv("NBI_TMPDIR", str(tmp_path))
        job = Job(name="arr", command=f"echo $SLURM_ARRAY_TASK_ID:#FILE# >> {tmp_path}/out",
                  opts=Opts.new(threads=1, memory="1GB", time="1h"),
                  files=["x", "y"], sim_duration_s=10)
        job.run(exec_sim)
        exec_sim.run_until_idle()
        lines = sorted((tmp_path / "out").read_text().split())
        assert lines == ["0:x", "1:y"]


class TestEventCalendar:
    """The heap-calendar hot paths: numeric completion order across id
    digit-count boundaries, the name→node index, and wake_at at scale."""

    def test_completion_order_across_digit_boundary(self):
        """Jobs 9999999 and 10000000 finish together: numeric id order,
        not lexicographic ("10000000" < "9999999" as strings — the
        pre-calendar sort keyed on the jobid string)."""
        sim = SimCluster(nodes=[SimNode("n0", cpus=8)])
        sim._next_id = 9_999_999
        a = mkjob("a", duration=60, cpus=1).run(sim)  # 9999999
        b = mkjob("b", duration=60, cpus=1).run(sim)  # 10000000
        assert (a, b) == (9_999_999, 10_000_000)
        sim.advance(120)
        finishes = [msg for _, msg in sim.events_log if msg.startswith("finish")]
        assert finishes == [
            "finish 9999999 state=COMPLETED",
            "finish 10000000 state=COMPLETED",
        ]
        term = [e.jobid for e in sim.bus.history if e.type == "COMPLETED"]
        assert term == ["9999999", "10000000"]

    def test_array_completion_order_across_boundary(self):
        sim = SimCluster(nodes=[SimNode("n0", cpus=16)])
        sim._next_id = 9_999_999
        opts = Opts.new(threads=1, memory="1GB", time="1h")
        opts.array_size = 3
        ids = []
        for name in ("early", "late"):  # bases 9999999 and 10000000
            ids.append(Job(name=name, command="true", opts=opts,
                           sim_duration_s=60).run(sim))
        sim.advance(120)
        done = [e.jobid for e in sim.bus.history if e.type == "COMPLETED"]
        expect = [f"{base}_{t}" for base in ids for t in range(3)]
        assert done == expect

    def test_node_lookup_is_indexed(self, sim):
        assert sim._node("n000") is sim.nodes[0]
        # callers may grow the topology directly; the index self-heals
        sim.nodes.append(SimNode("extra"))
        assert sim._node("extra") is sim.nodes[-1]
        try:
            sim._node("nope")
        except KeyError:
            pass
        else:
            raise AssertionError("unknown node must raise KeyError")

    def test_thousands_of_wakeups_cheap(self, sim):
        """wake_at deadlines go to the shared heap (deduplicated); a day
        with thousands of controller deadlines must stay near-instant —
        the pre-calendar list-append-then-sort made this quadratic."""
        import time as _t

        t0 = sim.now
        for i in range(5000):
            sim.wake_at(t0 + timedelta(seconds=10 + (i % 2500)))  # dupes too
        assert len(sim._wake_set) == 2500
        stops = []
        sim.add_tick_hook(lambda s, now: stops.append(now))
        w0 = _t.perf_counter()
        sim.advance(3600)
        wall = _t.perf_counter() - w0
        assert wall < 2.0
        assert len(set(stops)) == 2501  # every deadline + the target stop
        assert not sim._wake_set  # all consumed

    def test_wake_at_past_ignored(self, sim):
        sim.advance(100)
        sim.wake_at(sim.now - timedelta(seconds=1))
        sim.wake_at(sim.now)
        assert not sim._wake_set
