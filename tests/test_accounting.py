"""Accounting subsystem: HistoryStore, EnergyModel, sacct parsing,
collectors, RuntimePredictor, report aggregation, and the closed
submit → run → account → learn loop on the simulator."""

import json
from datetime import datetime, timedelta

import pytest

from repro.accounting import (
    EnergyModel,
    HistoryStore,
    JobRecord,
    RuntimePredictor,
    collect,
    name_stem,
    parse_consumed_energy,
    predictor_from_config,
    report_dict,
    render_report,
    synthetic_trace,
)
from repro.core import (
    EcoScheduler,
    Job,
    Opts,
    SimCluster,
    SubmitEngine,
    parse_sacct_output,
)

NOW = datetime(2026, 3, 18, 10, 0)  # Wednesday morning

SCHED = dict(
    weekday_windows=[(0, 360)], weekend_windows=[(0, 420), (660, 960)],
    peak_hours=[(1020, 1200)], horizon_days=14, min_delay_s=0,
)


def make_record(i=0, **kw):
    defaults = dict(
        jobid=str(1000 + i), name=f"blast-{i}", user="alice",
        state="COMPLETED", cpus=4, time_limit_s=12 * 3600, runtime_s=3600,
        started_at="2026-03-18T00:00:00", finished_at="2026-03-18T01:00:00",
        requested_start="2026-03-17T10:00:00",
    )
    defaults.update(kw)
    return JobRecord(**defaults)


# ---------------------------------------------------------------------------
# HistoryStore
# ---------------------------------------------------------------------------


class TestHistoryStore:
    def test_append_scan_roundtrip(self, tmp_path):
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append(make_record(0))
        store.append_many([make_record(1), make_record(2)])
        recs = list(store.scan())
        assert [r.jobid for r in recs] == ["1000", "1001", "1002"]
        assert recs[0] == make_record(0)
        assert len(store) == 3

    def test_unknown_keys_ignored_missing_defaulted(self, tmp_path):
        p = tmp_path / "h.jsonl"
        p.write_text(
            json.dumps({"jobid": "7", "state": "COMPLETED", "new_field": 1}) + "\n"
        )
        (rec,) = HistoryStore(p).scan()
        assert rec.jobid == "7" and rec.cpus == 1 and rec.energy_kwh == 0.0

    def test_torn_line_skipped(self, tmp_path):
        p = tmp_path / "h.jsonl"
        good = json.dumps(make_record(0).to_dict())
        p.write_text(good + "\n" + good[: len(good) // 2])  # torn final line
        assert len(HistoryStore(p)) == 1

    def test_missing_file_is_empty(self, tmp_path):
        store = HistoryStore(tmp_path / "nope.jsonl")
        assert list(store.scan()) == [] and store.ids() == set()

    def test_filters(self, tmp_path):
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append_many([
            make_record(0, user="alice", state="COMPLETED"),
            make_record(1, user="bob", state="FAILED"),
            make_record(2, user="alice", tool="kraken2",
                        started_at="2026-04-01T00:00:00"),
        ])
        assert len(store.records(user="alice")) == 2
        assert len(store.records(state="FAILED")) == 1
        assert len(store.records(tool="kraken2")) == 1
        assert len(store.records(since=datetime(2026, 4, 1))) == 1

    def test_env_override_is_default_path(self, tmp_path, monkeypatch):
        monkeypatch.setenv("NBI_HISTORY", str(tmp_path / "env.jsonl"))
        assert HistoryStore().path == tmp_path / "env.jsonl"


# ---------------------------------------------------------------------------
# EnergyModel
# ---------------------------------------------------------------------------


class TestEnergyModel:
    def test_cpu_time_tdp_model(self):
        m = EnergyModel(watts_per_cpu=10.0, trace=None)
        # 4 cpus × 10 W × 3600 s = 144 kJ = 0.04 kWh
        assert m.energy_kwh(4, 3600) == pytest.approx(0.04)

    def test_consumed_energy_suffixes(self):
        assert parse_consumed_energy("") == 0.0
        assert parse_consumed_energy("1234") == 1234.0
        assert parse_consumed_energy("2.43K") == pytest.approx(2430.0)
        assert parse_consumed_energy("3M") == pytest.approx(3e6)
        assert parse_consumed_energy("garbage") == 0.0

    def test_synthetic_trace_shape(self):
        trace = synthetic_trace()
        assert len(trace.hourly) == 168
        # evening peak costs more than the small hours, weekends are cheaper
        mon_3am = trace.at(datetime(2026, 3, 16, 3))
        mon_6pm = trace.at(datetime(2026, 3, 16, 18))
        sat_6pm = trace.at(datetime(2026, 3, 21, 18))
        assert mon_6pm > mon_3am
        assert sat_6pm < mon_6pm

    def test_annotate_counterfactual_nonzero_saving(self):
        m = EnergyModel()
        # deferred: ran 00:00-01:00, would have run from 10:00 without eco
        rec = make_record(eco_deferred=True, eco_tier=1)
        m.annotate(rec)
        assert rec.energy_kwh > 0
        assert rec.carbon_gco2 > 0
        assert rec.carbon_nodefer_gco2 > rec.carbon_gco2  # night < daytime
        assert rec.carbon_saved_gco2 > 0

    def test_non_deferred_job_has_zero_saving(self):
        """Queue-wait drift on a job eco never touched must not be
        (mis)attributed to eco mode."""
        m = EnergyModel()
        rec = make_record(eco_deferred=False)  # started later than requested
        m.annotate(rec)
        assert rec.carbon_nodefer_gco2 == rec.carbon_gco2
        assert rec.carbon_saved_gco2 == 0.0

    def test_measured_energy_preserved(self):
        m = EnergyModel()
        rec = make_record(energy_kwh=0.5)
        m.annotate(rec)
        assert rec.energy_kwh == 0.5


# ---------------------------------------------------------------------------
# sacct parsing
# ---------------------------------------------------------------------------

SACCT_SAMPLE = """\
123|align|alice|main|8|16000M|12:00:00|2026-03-18T09:00:00|2026-03-19T00:00:00|2026-03-19T01:00:00|COMPLETED|3600|0|n001
123.batch|batch|||8||||2026-03-19T00:00:00|2026-03-19T01:00:00|COMPLETED|3600|2.43K|n001
124|oom|bob|main|4|8G|06:00:00|2026-03-18T10:00:00|2026-03-18T11:00:00|2026-03-18T11:30:00|FAILED|1800|0|n002
125|still|bob|main|4|8G|06:00:00|2026-03-18T10:00:00|2026-03-18T11:00:00|Unknown|RUNNING|900|0|n003
"""


class TestSacctParsing:
    def test_rows_normalised_steps_folded(self):
        rows = parse_sacct_output(SACCT_SAMPLE)
        assert [r["jobid"] for r in rows] == ["123", "124", "125"]
        r = rows[0]
        assert r["cpus"] == 8
        assert r["memory_mb"] == 16000
        assert r["time_limit_s"] == 12 * 3600
        assert r["elapsed_s"] == 3600
        # batch-step energy backfills the parent
        assert parse_consumed_energy(r["consumed_energy"]) == pytest.approx(2430.0)

    def test_per_cpu_reqmem_multiplied(self):
        line = ("200|x|alice|main|8|4Gc|01:00:00|2026-03-18T09:00:00|"
                "2026-03-18T10:00:00|2026-03-18T11:00:00|COMPLETED|3600|0|n001")
        (row,) = parse_sacct_output(line + "\n")
        assert row["memory_mb"] == 8 * 4096  # 4G per CPU × 8 CPUs
        per_node = parse_sacct_output(line.replace("4Gc", "4Gn") + "\n")
        assert per_node[0]["memory_mb"] == 4096

    def test_out_of_memory_is_terminal_failure(self, tmp_path):
        line = ("201|oom|bob|main|4|8G|01:00:00|2026-03-18T09:00:00|"
                "2026-03-18T10:00:00|2026-03-18T10:30:00|OUT_OF_ME+|1800|0|n001")

        class FakeSlurm:
            def accounting(self):
                return parse_sacct_output(line + "\n")

        store = HistoryStore(tmp_path / "h.jsonl")
        assert collect(FakeSlurm(), store) == 1
        (rec,) = store.scan()
        assert rec.state == "OUT_OF_MEMORY" and rec.is_terminal
        rep = report_dict([rec], by="user")
        assert rep["total"]["failed"] == 1

    def test_collect_forwards_since_when_supported(self, tmp_path):
        calls = {}

        class FakeSlurm:
            def accounting(self, *, since=""):
                calls["since"] = since
                return []

        collect(FakeSlurm(), HistoryStore(tmp_path / "h.jsonl"),
                since="2026-01-01")
        assert calls["since"] == "2026-01-01"
        # simulator-style accounting() without the parameter still works
        sim_calls = []

        class NoSince:
            def accounting(self):
                sim_calls.append(True)
                return []

        collect(NoSince(), HistoryStore(tmp_path / "h2.jsonl"),
                since="2026-01-01")
        assert sim_calls == [True]

    def test_collect_from_sacct_rows(self, tmp_path):
        class FakeSlurm:
            def accounting(self):
                return parse_sacct_output(SACCT_SAMPLE)

        store = HistoryStore(tmp_path / "h.jsonl")
        n = collect(FakeSlurm(), store, EnergyModel())
        assert n == 2  # RUNNING row not archived
        recs = {r.jobid: r for r in store.scan()}
        assert recs["123"].state == "COMPLETED"
        assert recs["123"].energy_kwh == pytest.approx(2430.0 / 3.6e6)
        assert recs["124"].state == "FAILED"
        # modelled energy fills the gap where sacct reported none
        assert recs["124"].energy_kwh > 0


# ---------------------------------------------------------------------------
# RuntimePredictor
# ---------------------------------------------------------------------------


class TestRuntimePredictor:
    def test_empty_store_returns_request_limit(self, tmp_path):
        p = RuntimePredictor(HistoryStore(tmp_path / "h.jsonl"))
        assert p.predict(12 * 3600, name="blast-1", user="alice") == 12 * 3600

    def test_below_min_samples_returns_limit(self, tmp_path):
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append_many([make_record(i) for i in range(2)])
        p = RuntimePredictor(store, min_samples=3)
        assert p.predict(12 * 3600, name="blast-9") == 12 * 3600

    def test_learns_percentile_with_margin(self, tmp_path):
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append_many([make_record(i, runtime_s=3600) for i in range(5)])
        p = RuntimePredictor(store)
        est = p.predict(12 * 3600, name="blast-77", user="alice")
        assert est == 4500  # 3600 × 1.25, already whole minutes

    def test_never_exceeds_request_limit(self, tmp_path):
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append_many([make_record(i, runtime_s=10 * 3600) for i in range(5)])
        p = RuntimePredictor(store)
        assert p.predict(3600, name="blast-1") == 3600

    def test_only_completed_runs_count(self, tmp_path):
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append_many(
            [make_record(i, state="TIMEOUT", runtime_s=12 * 3600) for i in range(5)]
        )
        p = RuntimePredictor(store)
        assert p.predict(12 * 3600, name="blast-1") == 12 * 3600

    def test_name_stem_groups_sweeps(self):
        assert name_stem("align-17") == "align"
        assert name_stem("align_3") == "align"
        assert name_stem("job") == "job"
        assert name_stem("42") == "42"  # all-digit names fall back to themselves
        # digit-ending base names key as themselves (no separator stripped)
        assert name_stem("kraken2") == "kraken2"
        assert name_stem("kraken2-0") == "kraken2"
        # idempotent: indexing key == lookup key, always
        for n in ("align-17", "kraken2", "kraken2-0", "x-1-2", "job"):
            assert name_stem(name_stem(n)) == name_stem(n)

    def test_digit_ending_batch_names_learn(self, tmp_path):
        """runjob --from-file names tasks kraken2-0..N; a later submission
        of plain 'kraken2' must hit that history."""
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append_many([make_record(i, name=f"kraken2-{i}", runtime_s=1800)
                           for i in range(5)])
        p = RuntimePredictor(store)
        assert p.predict(12 * 3600, name="kraken2") < 12 * 3600

    def test_user_scoped_history_preferred(self, tmp_path):
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append_many(
            [make_record(i, user="alice", runtime_s=3600) for i in range(3)]
            + [make_record(10 + i, user="bob", runtime_s=7200) for i in range(3)]
        )
        p = RuntimePredictor(store)
        assert p.predict(12 * 3600, name="blast-1", user="alice") < p.predict(
            12 * 3600, name="blast-1", user="bob"
        )

    def test_predictor_from_config_none_without_history(self):
        # conftest points NBI_HISTORY at a nonexistent tmp file
        assert predictor_from_config() is None


# ---------------------------------------------------------------------------
# EcoScheduler + predictor
# ---------------------------------------------------------------------------


class TestEcoPredictorIntegration:
    def test_no_predictor_decide_equals_next_window(self):
        s = EcoScheduler(**SCHED)
        assert s.decide(6 * 3600, NOW, name="x", user="y") == s.next_window(
            6 * 3600, NOW
        )

    def test_empty_history_bit_identical(self, tmp_path):
        plain = EcoScheduler(**SCHED)
        pred = EcoScheduler(
            **SCHED, predictor=RuntimePredictor(HistoryStore(tmp_path / "h.jsonl"))
        )
        for dur in (1800, 6 * 3600, 12 * 3600, 3 * 86400):
            assert pred.decide(dur, NOW, name="blast-1", user="a") == \
                plain.next_window(dur, NOW)
        assert pred.decide_many(
            [1800, 6 * 3600, 12 * 3600], NOW,
            keys=[("a-1", "u"), ("b-2", "u"), ("c-3", "u")],
        ) == plain.decide_many([1800, 6 * 3600, 12 * 3600], NOW)

    def test_history_lifts_padded_job_to_tier1(self, tmp_path):
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append_many([make_record(i, runtime_s=3000) for i in range(5)])
        plain = EcoScheduler(**SCHED)
        pred = EcoScheduler(**SCHED, predictor=RuntimePredictor(store))
        before = plain.next_window(12 * 3600, NOW)
        after = pred.decide(12 * 3600, NOW, name="blast-9", user="alice")
        assert before.tier == 2  # 12 h cannot complete in a 6 h window
        assert after.tier == 1  # predicted ~63 min completes easily

    def test_decide_many_keys_mismatch_raises(self):
        s = EcoScheduler(**SCHED)
        with pytest.raises(ValueError):
            s.decide_many([3600], NOW, keys=[("a", "u"), ("b", "u")])


# ---------------------------------------------------------------------------
# SimCluster energy emission + collect
# ---------------------------------------------------------------------------


class TestSimEnergy:
    def submit_one(self, sim, duration_s=3600, **optkw):
        opts = Opts.new(threads=4, memory="4GB", time="2h", **optkw)
        job = Job(name="e", command="true", opts=opts, sim_duration_s=duration_s)
        job.prepare()
        return sim.submit(job)

    def test_energy_charged_at_completion(self, sim):
        self.submit_one(sim, duration_s=3600)
        sim.run_until_idle()
        (j,) = [j for j in sim.accounting() if j.state == "COMPLETED"]
        assert j.energy_j == pytest.approx(sim.watts_per_cpu * 4 * 3600)

    def test_energy_on_cancel_is_elapsed_only(self, sim):
        base = self.submit_one(sim, duration_s=7200)
        sim.advance(1800)
        sim.cancel([base])
        j = sim.get(base)
        assert j.state == "CANCELLED"
        assert j.energy_j == pytest.approx(sim.watts_per_cpu * 4 * 1800)

    def test_requeued_job_charged_per_attempt(self, sim):
        base = self.submit_one(sim, duration_s=3600)
        sim.advance(600)
        j = sim.get(base)
        sim.fail_node(j.node)
        sim.restore_node([n.name for n in sim.nodes][0])
        sim.run_until_idle()
        j = sim.get(base)
        assert j.state == "COMPLETED"
        # 600 s wasted partial run + 3600 s successful rerun
        assert j.energy_j == pytest.approx(sim.watts_per_cpu * 4 * 4200)

    def test_eco_meta_flows_to_simjob(self, sim):
        opts = Opts.new(threads=1, memory="1GB", time="1h")
        job = Job(name="m", command="true", opts=opts)
        job.eco_meta = {"tier": 1, "deferred": True}
        job.tool = "kraken2"
        job.prepare()
        base = sim.submit(job)
        j = sim.get(base)
        assert j.eco_tier == 1 and j.eco_deferred and j.tool == "kraken2"

    def test_collect_dedup_and_annotation(self, sim, tmp_path):
        self.submit_one(sim)
        sim.run_until_idle()
        store = HistoryStore(tmp_path / "h.jsonl")
        assert collect(sim, store) == 1
        assert collect(sim, store) == 0
        (rec,) = store.scan()
        assert rec.energy_kwh > 0 and rec.carbon_gco2 > 0
        assert rec.runtime_s == 3600
        assert rec.user == "testuser"


# ---------------------------------------------------------------------------
# Reports + the closed loop
# ---------------------------------------------------------------------------


class TestReport:
    def test_aggregate_by_user_and_tool(self):
        recs = [
            make_record(0, user="alice", energy_kwh=1.0, carbon_gco2=10.0,
                        carbon_nodefer_gco2=15.0, eco_deferred=True),
            make_record(1, user="bob", name="qc-1", energy_kwh=2.0,
                        carbon_gco2=30.0, carbon_nodefer_gco2=30.0),
        ]
        rep = report_dict(recs, by="user")
        assert {g["key"] for g in rep["groups"]} == {"alice", "bob"}
        assert rep["total"]["energy_kwh"] == pytest.approx(3.0)
        assert rep["total"]["carbon_saved_gco2"] == pytest.approx(5.0)
        assert rep["total"]["eco_deferred"] == 1
        by_tool = report_dict(recs, by="tool")
        assert {g["key"] for g in by_tool["groups"]} == {"blast", "qc"}

    def test_render_report_table(self):
        out = render_report([make_record(0)], by="user", color=False)
        assert "alice" in out and "Saved(g)" in out and "1 job(s)" in out

    def test_thousand_job_sim_history_reports_nonzero_savings(self, tmp_path):
        """Acceptance: simulated 1k-job history → nonzero energy, carbon,
        and eco-mode savings in the report payload."""
        sim = SimCluster(now=datetime(2026, 3, 16, 9, 0), default_user="alice")
        for node in sim.nodes:
            node.cpus = 2048
        engine = SubmitEngine(
            sim, eco=True, coalesce=False,
            scheduler=EcoScheduler(**SCHED), now=sim.now,
        )
        jobs = [
            Job(name=f"etl-{i % 7}", command="true",
                opts=Opts.new(threads=2, memory="2GB", time="4h"),
                sim_duration_s=1800 + (i % 5) * 600)
            for i in range(1000)
        ]
        result = engine.submit_many(jobs)
        assert result.eco_deferred == 1000
        sim.run_until_idle(max_days=40)
        store = HistoryStore(tmp_path / "h.jsonl")
        assert collect(sim, store) == 1000
        rep = report_dict(store.records(), by="tool")
        tot = rep["total"]
        assert tot["jobs"] == 1000
        assert tot["energy_kwh"] > 0
        assert tot["carbon_gco2"] > 0
        assert tot["carbon_saved_gco2"] > 0
        assert tot["eco_deferred"] == 1000

    def test_engine_predictor_changes_batch_decisions(self, tmp_path):
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append_many([make_record(i, name="etl-1", runtime_s=1800)
                           for i in range(5)])
        sim = SimCluster(now=NOW, default_user="alice")
        # predictor= must take effect even beside a supplied scheduler
        engine = SubmitEngine(
            sim, eco=True, coalesce=False,
            scheduler=EcoScheduler(**SCHED),
            predictor=RuntimePredictor(store),
            now=NOW,
        )
        jobs = [Job(name=f"etl-{i}", command="true",
                    opts=Opts.new(threads=1, memory="1GB", time="12h"))
                for i in range(3)]
        engine.submit_many(jobs)
        tiers = {sim.get(j.jobid).eco_tier for j in jobs}
        assert tiers == {1}  # predicted 30 min → completes in night window


class TestSubmitLogJournal:
    """Real SLURM cannot report the eco decision back through sacct — the
    SubmitLog journal written at submission time restores it at collect."""

    SACCT_LINE = (
        "300|annotate|alice|main|4|8G|12:00:00|2026-03-18T10:00:00|"
        "2026-03-19T00:00:00|2026-03-19T01:00:00|COMPLETED|3600|0|n001"
    )

    def test_journal_restores_eco_meta_and_savings(self, tmp_path):
        store = HistoryStore(tmp_path / "h.jsonl")
        store.submit_log().log("300", tool="prokka",
                               eco_meta={"tier": 1, "deferred": True})

        class FakeSlurm:
            def accounting(inner):
                return parse_sacct_output(self.SACCT_LINE + "\n")

        assert collect(FakeSlurm(), store, EnergyModel()) == 1
        (rec,) = store.scan()
        assert rec.tool == "prokka"
        assert rec.eco_deferred and rec.eco_tier == 1
        # deferred 10:00 → 00:00: the counterfactual now differs
        assert rec.carbon_saved_gco2 > 0

    def test_unjournaled_job_keeps_defaults(self, tmp_path):
        store = HistoryStore(tmp_path / "h.jsonl")

        class FakeSlurm:
            def accounting(inner):
                return parse_sacct_output(self.SACCT_LINE + "\n")

        collect(FakeSlurm(), store, EnergyModel())
        (rec,) = store.scan()
        assert not rec.eco_deferred and rec.carbon_saved_gco2 == 0.0

    def test_runjob_journals_eco_submissions(self, monkeypatch, tmp_path,
                                             capsys):
        from repro.cli import runjob

        monkeypatch.setenv("NBI_HISTORY", str(tmp_path / "h.jsonl"))
        runjob.main(["-n", "night", "-t", "2",
                     "--now", "2026-03-18T10:00:00", "true"])
        jid = capsys.readouterr().out.strip().splitlines()[-1]
        journal = HistoryStore(tmp_path / "h.jsonl").submit_log().load()
        assert journal[jid]["eco_deferred"] is True

    def test_launcher_journals_tool_name(self, monkeypatch, tmp_path):
        from repro.core.launcher import Kraken2

        monkeypatch.setenv("NBI_HISTORY", str(tmp_path / "h.jsonl"))
        monkeypatch.setenv("KRAKEN2_DB", str(tmp_path))
        lk = Kraken2(reads1="r1.fq", outdir=str(tmp_path), now=NOW)
        jid = lk.submit()
        journal = HistoryStore(tmp_path / "h.jsonl").submit_log().load()
        assert journal[str(jid)]["tool"] == "kraken2"


class TestToolNameMatching:
    def test_digit_suffixed_tool_matches_its_history(self, tmp_path):
        """tool= matches the archive's tool column verbatim."""
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append_many([
            make_record(i, name="kraken2", tool="kraken2", runtime_s=1800)
            for i in range(5)
        ])
        sched = EcoScheduler(**SCHED, predictor=RuntimePredictor(store))
        assert sched.effective_duration(12 * 3600, tool="kraken2") < 12 * 3600
        d = sched.decide(12 * 3600, NOW, tool="kraken2")
        assert d.tier == 1

    def test_records_tool_filter_matches_report_key(self, tmp_path):
        """--tool must accept exactly the key --by tool displayed."""
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append_many([make_record(i, name=f"align-{i}") for i in range(3)])
        rep = report_dict(store.records(), by="tool")
        key = rep["groups"][0]["key"]
        assert key == "align"
        assert len(store.records(tool=key)) == 3

    def test_engine_batch_keys_include_tool(self, tmp_path):
        """The batched eco path must hit tool-keyed history, same as the
        single-job Launcher path."""
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append_many([
            make_record(i, name="wrapped", tool="kraken2", runtime_s=1800)
            for i in range(5)
        ])
        sim = SimCluster(now=NOW, default_user="alice")
        engine = SubmitEngine(sim, eco=True, coalesce=False,
                              scheduler=EcoScheduler(**SCHED),
                              predictor=RuntimePredictor(store), now=NOW)
        job = Job(name="some-other-name", command="true",
                  opts=Opts.new(threads=1, memory="1GB", time="12h"))
        job.tool = "kraken2"
        engine.submit_many([job])
        assert sim.get(job.jobid).eco_tier == 1  # priced at ~30 min history


class TestSchedulerNotMutated:
    def test_engine_prices_through_a_copy(self, tmp_path):
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append_many([make_record(i, name="etl-1", runtime_s=1800)
                           for i in range(5)])
        caller_sched = EcoScheduler(**SCHED)
        sim = SimCluster(now=NOW, default_user="alice")
        engine = SubmitEngine(sim, eco=True, coalesce=False,
                              scheduler=caller_sched,
                              predictor=RuntimePredictor(store), now=NOW)
        engine.submit_many([Job(name="etl-0", command="true",
                                opts=Opts.new(threads=1, memory="1GB",
                                              time="12h"))])
        assert caller_sched.predictor is None  # caller's object untouched


class TestFinalReviewFixes:
    def test_predict_never_exceeds_subminute_limit(self, tmp_path):
        store = HistoryStore(tmp_path / "h.jsonl")
        store.append_many([make_record(i, name="quick-1", runtime_s=10)
                           for i in range(5)])
        p = RuntimePredictor(store)
        assert p.predict(30, name="quick-9") == 30  # limit wins over floor

    def test_files_array_journaled_per_task(self, monkeypatch, tmp_path,
                                            capsys):
        from repro.cli import runjob

        monkeypatch.setenv("NBI_HISTORY", str(tmp_path / "h.jsonl"))
        listing = tmp_path / "samples.txt"
        listing.write_text("a.fq\nb.fq\nc.fq\n")
        runjob.main(["-n", "arr", "-t", "2", "--files", str(listing),
                     "--now", "2026-03-18T10:00:00", "cmd #FILE#"])
        base = capsys.readouterr().out.strip().splitlines()[-1]
        journal = HistoryStore(tmp_path / "h.jsonl").submit_log().load()
        assert set(journal) == {f"{base}_{t}" for t in range(3)}
        assert all(e["eco_deferred"] for e in journal.values())

    def test_collect_reads_default_sidecar_for_custom_history(
            self, monkeypatch, tmp_path):
        """ecoreport --history X --collect must still see eco decisions
        journaled to the configured default archive."""
        monkeypatch.setenv("NBI_HISTORY", str(tmp_path / "default.jsonl"))
        from repro.accounting import log_submission

        log_submission("300", tool="prokka",
                       eco_meta={"tier": 1, "deferred": True})
        line = ("300|annotate|alice|main|4|8G|12:00:00|2026-03-18T10:00:00|"
                "2026-03-19T00:00:00|2026-03-19T01:00:00|COMPLETED|3600|0|n1")

        class FakeSlurm:
            def accounting(self):
                return parse_sacct_output(line + "\n")

        custom = HistoryStore(tmp_path / "custom.jsonl")
        assert collect(FakeSlurm(), custom) == 1
        (rec,) = custom.scan()
        assert rec.eco_deferred and rec.tool == "prokka"


class TestSacctRegressions:
    """Satellite: NodeList oddities and orphan/out-of-order job steps."""

    LINE = ("300|aln|alice|main|4|8G|01:00:00|2026-03-18T09:00:00|"
            "{start}|{end}|{state}|{elapsed}|{energy}|{node}")

    def _line(self, jobid="300", state="COMPLETED", elapsed="3600",
              energy="0", node="n001",
              start="2026-03-18T10:00:00", end="2026-03-18T11:00:00"):
        return self.LINE.format(
            start=start, end=end, state=state, elapsed=elapsed,
            energy=energy, node=node,
        ).replace("300", jobid, 1)

    def test_nodelist_none_assigned_normalised_empty(self):
        # sacct prints "None assigned" for jobs that never started
        (row,) = parse_sacct_output(
            self._line(state="CANCELLED", elapsed="0", node="None assigned",
                       start="Unknown", end="2026-03-18T11:00:00") + "\n"
        )
        assert row["node"] == ""
        assert row["started_at"] == ""

    def test_nodelist_none_normalised_empty(self):
        (row,) = parse_sacct_output(self._line(node="None") + "\n")
        assert row["node"] == ""

    def test_orphan_batch_step_produces_no_row(self):
        # the parent row was filtered out (e.g. --user scoping): the
        # orphan step must neither crash nor fabricate a job row
        text = (
            "999.batch|batch|||4||||2026-03-18T10:00:00|2026-03-18T11:00:00"
            "|COMPLETED|3600|5.00K|n001\n"
            "999.extern|extern|||4||||2026-03-18T10:00:00|2026-03-18T11:00:00"
            "|COMPLETED|3600|0|n001\n"
        )
        assert parse_sacct_output(text) == []

    def test_step_before_parent_still_backfills_energy(self):
        # step order is not guaranteed: a .batch step arriving before its
        # parent row must still donate its ConsumedEnergy
        step = ("300.batch|batch|||4||||2026-03-18T10:00:00|"
                "2026-03-18T11:00:00|COMPLETED|3600|7.20K|n001")
        text = step + "\n" + self._line() + "\n"
        (row,) = parse_sacct_output(text)
        assert row["jobid"] == "300"
        assert parse_consumed_energy(row["consumed_energy"]) == pytest.approx(7200.0)

    def test_parent_measured_energy_not_overwritten_by_step(self):
        step = ("300.batch|batch|||4||||2026-03-18T10:00:00|"
                "2026-03-18T11:00:00|COMPLETED|3600|7.20K|n001")
        text = self._line(energy="9.00K") + "\n" + step + "\n"
        (row,) = parse_sacct_output(text)
        assert parse_consumed_energy(row["consumed_energy"]) == pytest.approx(9000.0)

    def test_energyless_steps_are_ignored(self):
        text = (
            "301.extern|extern|||4||||2026-03-18T10:00:00|"
            "2026-03-18T11:00:00|COMPLETED|3600|0|n001\n"
            + self._line(jobid="301") + "\n"
        )
        (row,) = parse_sacct_output(text)
        assert row["jobid"] == "301"
        assert parse_consumed_energy(row["consumed_energy"]) == 0.0
