"""Serving engine: generation correctness, batching determinism, cache pad."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve import ServeEngine, pad_cache_to
from repro.models.registry import build_model


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("codeqwen1.5-7b")
    return ServeEngine(cfg, batch=2, max_seq=48, seed=0)


class TestPadCache:
    def test_pads_seq_axis(self):
        cfg = get_smoke_config("codeqwen1.5-7b")
        model = build_model(cfg)
        small = jax.tree_util.tree_map(
            lambda sd: jnp.ones((*sd.shape[:-2], 8, sd.shape[-1]), sd.dtype),
            model.cache_defs_fn(1, 8),
        )
        target = model.cache_defs_fn(1, 32)
        padded = pad_cache_to(small, target)
        for leaf, want in zip(
            jax.tree_util.tree_leaves(padded), jax.tree_util.tree_leaves(target)
        ):
            assert leaf.shape == want.shape
            np.testing.assert_array_equal(np.asarray(leaf)[..., 8:, :], 0)

    def test_oversize_rejected(self):
        cfg = get_smoke_config("codeqwen1.5-7b")
        model = build_model(cfg)
        big = jax.tree_util.tree_map(
            lambda sd: jnp.ones(sd.shape, sd.dtype), model.cache_defs_fn(1, 64)
        )
        with pytest.raises(ValueError, match="exceeds"):
            pad_cache_to(big, model.cache_defs_fn(1, 32))


class TestGeneration:
    def test_greedy_matches_step_by_step_forward(self, engine):
        """Engine generation must equal naive full-recompute greedy decode."""
        cfg = engine.cfg
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab_size, (2, 12)).astype(np.int32)
        out = engine.generate_batch(prompts.copy(), gen_len=6)

        # oracle: recompute the full forward for every generated token
        model = engine.model
        params = engine.params
        toks = jnp.asarray(prompts)
        want = []
        for _ in range(6):
            logits, _ = jax.jit(model.prefill_fn)(params, {"tokens": toks})
            nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            want.append(np.asarray(nxt))
            toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
        np.testing.assert_array_equal(out, np.stack(want, axis=1))

    def test_batch_independence(self, engine):
        """A row's output never depends on its batch-mates."""
        cfg = engine.cfg
        rng = np.random.default_rng(1)
        a = rng.integers(0, cfg.vocab_size, (12,)).astype(np.int32)
        b1 = rng.integers(0, cfg.vocab_size, (12,)).astype(np.int32)
        b2 = rng.integers(0, cfg.vocab_size, (12,)).astype(np.int32)
        out1 = engine.generate_batch(np.stack([a, b1]), gen_len=5)
        out2 = engine.generate_batch(np.stack([a, b2]), gen_len=5)
        np.testing.assert_array_equal(out1[0], out2[0])

    def test_serve_requests_order_and_determinism(self, engine):
        cfg = engine.cfg
        rng = np.random.default_rng(2)
        reqs = [
            rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in (5, 9, 5, 13, 9)
        ]
        outs = engine.serve_requests(reqs, gen_len=4)
        assert len(outs) == 5
        solo = engine.serve_requests([reqs[3]], gen_len=4)[0]
        np.testing.assert_array_equal(solo, outs[3])

    def test_temperature_sampling_valid_tokens(self, engine):
        cfg = engine.cfg
        prompts = np.ones((2, 8), np.int32)
        out = engine.generate_batch(prompts, gen_len=4, temperature=1.0)
        assert out.min() >= 0 and out.max() < cfg.vocab_size  # padded vocab ok

    def test_capacity_guard(self, engine):
        with pytest.raises(AssertionError):
            engine.generate_batch(np.ones((2, 47), np.int32), gen_len=5)


class TestRecurrentServing:
    def test_rwkv_generation_matches_full_forward(self):
        cfg = get_smoke_config("rwkv6-7b")
        engine = ServeEngine(cfg, batch=1, max_seq=32, seed=0)
        rng = np.random.default_rng(3)
        prompt = rng.integers(0, cfg.vocab_size, (1, 10)).astype(np.int32)
        out = engine.generate_batch(prompt.copy(), gen_len=4)

        model, params = engine.model, engine.params
        toks = jnp.asarray(prompt)
        for i in range(4):
            logits, _ = jax.jit(model.prefill_fn)(params, {"tokens": toks})
            nxt = int(jnp.argmax(logits[0, -1]))
            assert nxt == int(out[0, i])
            toks = jnp.concatenate([toks, jnp.full((1, 1), nxt, jnp.int32)], axis=1)


class TestContinuousBatching:
    def test_exact_vs_full_recompute(self):
        """Slot-based continuous batching must be bit-identical to greedy
        full-recompute decoding for every request, regardless of slot
        assignment and arrival order."""
        from repro.launch.serve import ContinuousBatchingEngine

        cfg = get_smoke_config("codeqwen1.5-7b")
        rng = np.random.default_rng(3)
        reqs = [rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
                for n in (12, 5, 9, 12, 7)]
        cb = ContinuousBatchingEngine(cfg, batch=2, max_seq=48, seed=0)
        outs = cb.serve(reqs, gen_len=4)

        model, params = cb.model, cb.params
        for i, req in enumerate(reqs):
            toks = jnp.asarray(req[None, :])
            want = []
            for _ in range(4):
                logits, _ = jax.jit(model.prefill_fn)(params, {"tokens": toks})
                nxt = int(jnp.argmax(logits[0, -1]))
                want.append(nxt)
                toks = jnp.concatenate(
                    [toks, jnp.full((1, 1), nxt, jnp.int32)], axis=1
                )
            assert outs[i].tolist() == want, i

    def test_beats_static_batching_steps(self):
        """Mixed lengths through fixed slots: fewer decode steps than the
        static lower bound ceil(R/B)·gen (no waiting on batch-mates)."""
        from repro.launch.serve import ContinuousBatchingEngine

        cfg = get_smoke_config("codeqwen1.5-7b")
        rng = np.random.default_rng(4)
        reqs = [rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
                for n in (4, 16, 4, 16, 4, 16)]
        cb = ContinuousBatchingEngine(cfg, batch=3, max_seq=40, seed=0)
        cb.serve(reqs, gen_len=5)
        occupancy = cb.stats["occupancy_sum"] / cb.stats["decode_steps"]
        assert occupancy > 0.8
        assert cb.stats["decode_steps"] <= -(-len(reqs) // 3) * 5 + 2

    def test_moe_rejected(self):
        from repro.launch.serve import ContinuousBatchingEngine

        with pytest.raises(AssertionError):
            ContinuousBatchingEngine(
                get_smoke_config("deepseek-moe-16b"), batch=2, max_seq=32
            )


class TestVectorPos:
    @pytest.mark.parametrize("arch", ["codeqwen1.5-7b", "minicpm3-4b"])
    def test_vector_pos_equals_per_row_scalar(self, arch):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, S = 3, 24
        rng = np.random.default_rng(1)
        cache = jax.tree_util.tree_map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype), model.cache_defs_fn(B, S)
        )
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        posv = jnp.asarray([2, 7, 11])
        lm, _ = jax.jit(model.decode_fn)(params, cache, tok, posv)
        for b in range(B):
            cb = jax.tree_util.tree_map(
                lambda x: x[:, b:b + 1] if x.ndim >= 2 else x, cache
            )
            lb, _ = jax.jit(model.decode_fn)(
                params, cb, tok[b:b + 1], jnp.asarray(int(posv[b]))
            )
            np.testing.assert_allclose(
                np.asarray(lm[b]), np.asarray(lb[0]), atol=2e-5
            )
