"""SimCluster benchmark — the event-calendar scheduling hot path.

Three measurements (plus a 1M-job stress variant):

  1. **simulated day** — ``NBI_BENCH_DAY_JOBS`` jobs (default 100,000) in
     hourly cohorts straight into one SimCluster (no federation layer in
     the way: this times the simulator itself), drained with
     ``run_until_idle`` and checked for conservation (every submitted job
     reaches COMPLETED exactly once, energy charged for all of them);
  2. **head-to-head vs the reference** — the same deep-backlog workload
     (capacity ≪ submission rate, the pre-calendar worst case) through
     the production event-calendar scheduler and through
     ``repro.core.simref.ReferenceSimCluster`` (the original
     sort-everything implementation the equivalence suite pins against).
     ``speedup_ok`` gates ≥5×; the reference cost is quadratic in queue
     depth, so the ratio grows with ``NBI_BENCH_SIM_REF_JOBS``;
  3. **wake storm** — thousands of deduplicated ``wake_at`` controller
     deadlines consumed by one ``advance()`` (the pre-calendar
     list-append-then-sort made this quadratic too).

With ``NBI_STRESS_FULL=1`` the day is additionally run at 1,000,000 jobs
(the ROADMAP scale target) with the same conservation checks.
"""

from __future__ import annotations

import os
import time
from datetime import datetime

from repro.core import Job, Opts, SimCluster, SimNode
from repro.core.simref import ReferenceSimCluster

DAY_T0 = datetime(2026, 3, 18, 0, 0, 0)


def _day_jobs(hour: int, n: int) -> "list[Job]":
    return [
        Job(name=f"day-{hour:02d}-{i}", command="true",
            opts=Opts(threads=1 + (i % 4), memory_mb=2048,
                      time_s=1800 * (1 + i % 3)),
            sim_duration_s=300 + (i % 7) * 120)
        for i in range(n)
    ]


def simulated_day(total_jobs: "int | None" = None) -> dict:
    """Hourly cohorts into one 2,048-cpu simulator; drain; conserve."""
    total_jobs = total_jobs or int(os.environ.get("NBI_BENCH_DAY_JOBS", "100000"))
    sim = SimCluster(
        nodes=[SimNode(f"n{i:03d}", cpus=64, memory_mb=262144)
               for i in range(32)],
        now=DAY_T0, default_user="bench",
    )
    per_hour = total_jobs // 24
    submitted = 0
    t0 = time.perf_counter()
    for hour in range(24):
        n = per_hour + (total_jobs % 24 if hour == 23 else 0)
        jobs = _day_jobs(hour, n)
        submitted += len(sim.submit_many(jobs))
        sim.advance(3600)
    sim.run_until_idle(max_days=30)
    wall = time.perf_counter() - t0
    states: dict = {}
    for j in sim.jobs.values():
        states[j.state] = states.get(j.state, 0) + 1
    conserved = (
        submitted == total_jobs
        and len(sim.jobs) == total_jobs
        and states.get("COMPLETED", 0) == total_jobs
        and len(sim.accounting()) == total_jobs
        and all(j.energy_j > 0 for j in sim.jobs.values())
    )
    out = {
        "jobs": total_jobs,
        "wall_s": wall,
        "day_jobs_per_s": total_jobs / wall,
        "states": states,
        "conserved": conserved,
        "sched_passes": sim.sched_passes,
        "sched_considered": sim.sched_considered,
        "considered_per_job": sim.sched_considered / total_jobs,
    }
    print(f"  day: {total_jobs} jobs in {wall:.1f}s "
          f"({out['day_jobs_per_s']:.0f} jobs/s) | conserved={conserved} | "
          f"{out['considered_per_job']:.1f} considered/job")
    return out


def _deep_backlog(cls, n: int) -> float:
    """One undersized node, n short jobs: queue depth ≈ n for most of the
    run — the shape where the old full-sweep scheduler went quadratic."""
    sim = cls(nodes=[SimNode("n000", cpus=16, memory_mb=65536)], now=DAY_T0)
    jobs = [Job(name=f"ref-{i}", command="true",
                opts=Opts.new(threads=1, memory="1GB", time="1h"),
                sim_duration_s=60) for i in range(n)]
    t0 = time.perf_counter()
    sim.submit_many(jobs)
    sim.run_until_idle(max_days=30)
    wall = time.perf_counter() - t0
    assert all(j.state == "COMPLETED" for j in sim.jobs.values())
    return wall


def head_to_head(n: "int | None" = None) -> dict:
    n = n or int(os.environ.get("NBI_BENCH_SIM_REF_JOBS", "3000"))
    new_wall = min(_deep_backlog(SimCluster, n) for _ in range(2))
    ref_wall = _deep_backlog(ReferenceSimCluster, n)
    speedup = ref_wall / new_wall
    out = {
        "jobs": n,
        "new_wall_s": new_wall,
        "reference_wall_s": ref_wall,
        "speedup_vs_reference": speedup,
        "speedup_ok": speedup >= 5.0,
    }
    print(f"  head-to-head: {n}-job deep backlog {ref_wall:.2f}s → "
          f"{new_wall:.2f}s ({speedup:.1f}x, gate ≥5x)")
    return out


def wake_storm(n_deadlines: int = 20000) -> dict:
    sim = SimCluster(now=DAY_T0)
    from datetime import timedelta

    for i in range(n_deadlines):
        sim.wake_at(DAY_T0 + timedelta(seconds=1 + i % (n_deadlines // 2)))
    t0 = time.perf_counter()
    sim.advance(n_deadlines)
    wall = time.perf_counter() - t0
    out = {
        "deadlines": n_deadlines,
        "wall_s": wall,
        "wakeups_per_s": (n_deadlines // 2) / wall,
    }
    print(f"  wake storm: {n_deadlines} wake_at ({n_deadlines // 2} unique) "
          f"consumed in {wall:.2f}s ({out['wakeups_per_s']:.0f}/s)")
    return out


def run() -> dict:
    out: dict = {}
    out["day"] = simulated_day()
    out["reference"] = head_to_head()
    out["wake"] = wake_storm()
    if os.environ.get("NBI_STRESS_FULL"):
        out["stress_1m"] = simulated_day(1_000_000)
    return out


if __name__ == "__main__":
    run()
