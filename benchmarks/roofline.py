"""Roofline table from the dry-run artifacts (deliverable g).

Reads results/dryrun/*.json (written by repro.launch.dryrun), computes the
three terms per (arch × shape × mesh), identifies the bottleneck and the
useful-FLOP ratio, and writes results/roofline.md + results/roofline.json.

MODEL_FLOPS conventions (per step):
  train:   6·N·tokens   (fwd 2·N·T + bwd 4·N·T; N = active params)
  prefill: 2·N·tokens
  decode:  2·N·batch    (one new token per sequence)
"""

from __future__ import annotations

import glob
import json
from pathlib import Path

import sys

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.analysis.roofline import V5E, roofline_report  # noqa: E402

RESULTS = Path(__file__).resolve().parent.parent / "results"


def model_flops(rec: dict) -> float:
    n_active = rec["active_param_count"]
    if rec["kind"] == "train":
        return 6.0 * n_active * rec["batch"] * rec["seq"]
    if rec["kind"] == "prefill":
        return 2.0 * n_active * rec["batch"] * rec["seq"]
    return 2.0 * n_active * rec["batch"]  # decode: 1 token/row


def tokens_per_step(rec: dict) -> float:
    if rec["kind"] == "decode":
        return float(rec["batch"])
    return float(rec["batch"] * rec["seq"])


def load_cells(mesh: str = "single") -> list[dict]:
    out = []
    for f in sorted(glob.glob(str(RESULTS / "dryrun" / f"*__{mesh}.json"))):
        rec = json.loads(Path(f).read_text())
        rec["_file"] = f
        out.append(rec)
    return out


def analyse(rec: dict) -> dict:
    rep = roofline_report(
        per_device_flops=rec["hlo_flops_per_device"],
        per_device_hbm_bytes=rec["hlo_hbm_bytes_per_device"],
        per_device_wire_bytes=rec["collective_wire_bytes_per_device"],
        chips=rec["chips"],
        model_flops=model_flops(rec),
        tokens=tokens_per_step(rec),
    )
    rep.update(arch=rec["arch"], shape=rec["shape"], kind=rec["kind"],
               mesh=rec["mesh"])
    return rep


_SUGGEST = {
    "compute": "reduce recompute (selective remat) / raise arithmetic intensity",
    "memory": "shrink activation traffic: seq-parallel residual, bf16 stores, fused norms",
    "collective": "sequence-parallel RS/AG instead of TP all-reduce; overlap with compute",
}


def markdown_table(mesh: str = "single") -> str:
    rows = []
    for rec in load_cells(mesh):
        if rec.get("status") == "skipped":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | — | skipped | "
                f"{rec['skip_reason'][:46]} |"
            )
            continue
        if rec.get("status") != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | — | ERROR | |")
            continue
        rep = analyse(rec)
        rows.append(
            "| {arch} | {shape} | {c:.3f} | {m:.3f} | {l:.3f} | {mfu:.1%} | {bn} | {sg} |".format(
                arch=rep["arch"], shape=rep["shape"],
                c=rep["compute_s"], m=rep["memory_s"], l=rep["collective_s"],
                mfu=rep["roofline_fraction_mfu"], bn=rep["bottleneck"],
                sg=_SUGGEST[rep["bottleneck"]][:52],
            )
        )
    header = (
        f"| arch | shape | compute (s) | memory (s) | collective (s) | "
        f"roofline frac | bottleneck | lever |\n|---|---|---|---|---|---|---|---|"
    )
    return header + "\n" + "\n".join(rows)


def main() -> int:
    reports = []
    for mesh in ("single",):
        for rec in load_cells(mesh):
            if rec.get("status") == "ok":
                reports.append(analyse(rec))
    (RESULTS / "roofline.json").write_text(json.dumps(reports, indent=1))
    md = "# Roofline (single-pod 16×16, v5e constants)\n\n" + markdown_table("single")
    (RESULTS / "roofline.md").write_text(md + "\n")
    print(md)
    # headline stats
    if reports:
        worst = min(reports, key=lambda r: r["roofline_fraction_mfu"])
        best = max(reports, key=lambda r: r["roofline_fraction_mfu"])
        print(f"\nbest  roofline fraction: {best['arch']}/{best['shape']} "
              f"= {best['roofline_fraction_mfu']:.1%}")
        print(f"worst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"= {worst['roofline_fraction_mfu']:.2%}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
