"""Kernel benchmark: correctness (allclose vs oracle) + CPU wall-time of the
XLA paths, + the structural VMEM/roofline accounting for the Pallas kernels.

On this CPU container the Pallas kernels execute in interpret mode (Python),
so wall-clock comparisons of pallas-vs-XLA are meaningless; what IS
meaningful here:
  * allclose sweeps (correctness — also covered by tests, repeated here so
    the bench output records the error magnitudes),
  * XLA-path wall time (chunked-flash vs naive attention — the memory-bound
    win is visible even on CPU),
  * static VMEM-footprint accounting per kernel block configuration
    (the quantity that determines TPU occupancy).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import attention_ref, lru_ref, rmsnorm_ref, wkv6_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import lru_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.rwkv6_scan import wkv6_pallas
from repro.models.common import attention_chunked


def _timeit(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def kernel_correctness() -> list[dict]:
    rng = np.random.default_rng(0)
    out = []

    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    err = float(jnp.abs(
        flash_attention(q, k, v, block_q=64, block_k=64) - attention_ref(q, k, v)
    ).max())
    out.append({"kernel": "flash_attention", "shape": "1x4(gqa2)x256x64",
                "max_err": err})

    r = jnp.asarray(rng.standard_normal((2, 2, 256, 64)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((2, 2, 256, 64)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((2, 2, 256, 64)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.3, 0.999, (2, 2, 256, 64)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32)
    s0 = jnp.zeros((2, 2, 64, 64), jnp.float32)
    yp, sp = wkv6_pallas(r, kk, vv, w, u, s0)
    yr, sr = wkv6_ref(r, kk, vv, w, u, s0)
    out.append({"kernel": "rwkv6_scan", "shape": "2x2x256x64",
                "max_err": float(jnp.abs(yp - yr).max())})

    a = jnp.asarray(rng.uniform(0.2, 0.999, (2, 256, 512)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((2, 256, 512)) * 0.3, jnp.float32)
    h0 = jnp.zeros((2, 512), jnp.float32)
    hp, _ = lru_pallas(a, b, h0)
    hr, _ = lru_ref(a, b, h0)
    out.append({"kernel": "rglru_scan", "shape": "2x256x512",
                "max_err": float(jnp.abs(hp - hr).max())})

    x = jnp.asarray(rng.standard_normal((512, 2048)), jnp.float32)
    wgt = jnp.asarray(rng.standard_normal((2048,)), jnp.float32)
    out.append({"kernel": "rmsnorm", "shape": "512x2048",
                "max_err": float(jnp.abs(
                    rmsnorm_pallas(x, wgt) - rmsnorm_ref(x, wgt)).max())})

    from repro.kernels.moe_gating import moe_gating_pallas
    from repro.kernels.ref import moe_gating_ref

    logits = jnp.asarray(rng.standard_normal((2, 256, 64)), jnp.float32)
    ip, gp, pp = moe_gating_pallas(logits, top_k=6, capacity=32)
    ir, gr, pr = moe_gating_ref(logits, top_k=6, capacity=32)
    exact = bool(np.array_equal(np.asarray(ip), np.asarray(ir))
                 and np.array_equal(np.asarray(pp), np.asarray(pr)))
    out.append({"kernel": "moe_gating", "shape": "2x256xE64k6",
                "max_err": float(jnp.abs(gp - gr).max()) if exact else float("inf")})
    return out


def xla_attention_scaling() -> list[dict]:
    """Chunked-flash XLA path vs naive O(S²) materialisation."""
    rng = np.random.default_rng(1)
    rows = []
    for S in (512, 1024, 2048):
        q = jnp.asarray(rng.standard_normal((1, 4, S, 64)), jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((1, 4, S, 64)), jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((1, 4, S, 64)), jnp.bfloat16)
        t_chunk = _timeit(
            jax.jit(lambda q, k, v: attention_chunked(q, k, v, kv_chunk=512)),
            q, k, v,
        )
        t_naive = _timeit(jax.jit(lambda q, k, v: attention_ref(q, k, v)), q, k, v)
        rows.append({"seq": S, "chunked_ms": t_chunk * 1e3,
                     "naive_ms": t_naive * 1e3,
                     "peak_mem_ratio": round(S / 512, 1)})
    return rows


def vmem_budgets() -> list[dict]:
    """Static per-step VMEM bytes for each kernel's default blocking."""
    out = []
    bq = bk = 128
    d = 128
    out.append({
        "kernel": "flash_attention", "block": f"{bq}x{bk}xd{d}",
        "vmem_bytes": (bq * d + 2 * bk * d) * 4 + (bq * d + 2 * bq) * 4,
    })
    C, dk, dv = 64, 64, 64
    out.append({
        "kernel": "rwkv6_scan", "block": f"C{C} dk{dk} dv{dv}",
        "vmem_bytes": (4 * C * dk + C * dv + dk * dv) * 4 + C * C * dk * 4,
    })
    Cw, bw = 128, 512
    out.append({
        "kernel": "rglru_scan", "block": f"C{Cw} w{bw}",
        "vmem_bytes": (2 * Cw * bw + 2 * bw) * 4,
    })
    out.append({
        "kernel": "rmsnorm", "block": "128 rows x 12288",
        "vmem_bytes": 2 * 128 * 12288 * 4,
    })
    for rec in out:
        rec["vmem_mb"] = round(rec["vmem_bytes"] / 2**20, 2)
        rec["fits_16mb"] = rec["vmem_bytes"] < 16 * 2**20
    return out


def run() -> dict:
    out = {
        "correctness": kernel_correctness(),
        "xla_attention": xla_attention_scaling(),
        "vmem": vmem_budgets(),
    }
    for rec in out["correctness"]:
        print(f"  {rec['kernel']:16s} {rec['shape']:18s} max_err={rec['max_err']:.2e}")
    for rec in out["xla_attention"]:
        print(f"  attention S={rec['seq']:5d}: chunked {rec['chunked_ms']:7.1f} ms "
              f"vs naive {rec['naive_ms']:7.1f} ms")
    for rec in out["vmem"]:
        print(f"  VMEM {rec['kernel']:16s} {rec['block']:18s} "
              f"{rec['vmem_mb']:6.2f} MB fits<16MB={rec['fits_16mb']}")
    return out
