"""Event-dispatch benchmark: the event-driven core vs poll-everywhere.

Three measurements on a 1,000-job simulated day:

1. **waitjobs economics** — queue snapshots taken to see the whole batch
   finish: the old polling loop (one squeue per poll tick) vs blocking on
   terminal JobEvents (one snapshot to resolve the watch set). The
   acceptance bar is ≥10× fewer snapshots.
2. **bus dispatch throughput** — JobEvents delivered per second through an
   EventBus with realistic subscriber fan-out, vs the cost of ONE
   1,000-row snapshot diff: how many events one poll is worth.
3. **eco hold-and-release** — tier-deferred jobs submitted HELD and
   released reactively: every release at or before the static ``--begin``
   deadline (hard invariant), with the early-release share and mean lead
   time reported.
"""

from __future__ import annotations

import time
from datetime import datetime, timedelta

from repro.core import (
    EcoController,
    EcoScheduler,
    EventBus,
    Job,
    JobEvent,
    Opts,
    Queue,
    SimCluster,
    SimNode,
    diff_snapshots,
)
from repro.core.events import TERMINAL_EVENTS

T0 = datetime(2026, 3, 18, 8, 0, 0)  # a Wednesday morning


class CountingBackend:
    """Counts real queue() snapshots taken through it."""

    def __init__(self, inner):
        self.inner = inner
        self.calls = 0

    def queue(self):
        self.calls += 1
        return self.inner.queue()

    def __getattr__(self, name):
        return getattr(self.inner, name)


def day_sim(n_jobs: int = 1000) -> SimCluster:
    sim = SimCluster(nodes=[SimNode(f"n{i:03d}", cpus=128) for i in range(32)],
                     now=T0)
    opts = Opts.new(threads=2, memory="2GB", time="10h")
    jobs = [
        Job(name=f"day-{i}", command="true", opts=opts,
            sim_duration_s=600 + (i % 96) * 300)  # 10 min … 8 h spread
        for i in range(n_jobs)
    ]
    sim.submit_many(jobs)
    return sim


def bench_waitjobs_snapshots(n_jobs: int = 1000, poll_s: float = 300.0) -> dict:
    """Polling loop vs terminal-event wait over the same simulated day."""
    # -- polling path: one snapshot per tick until the queue drains
    polling = CountingBackend(day_sim(n_jobs))
    t0 = time.perf_counter()
    while True:
        q = Queue(backend=polling)
        if not any(j.is_active() for j in q):
            break
        polling.inner.advance(poll_s)
    poll_wall = time.perf_counter() - t0
    poll_snapshots = polling.calls

    # -- event path: one snapshot to resolve the watch set, then events
    from repro.cli.waitjobs import wait_for_events

    eventful = CountingBackend(day_sim(n_jobs))
    t0 = time.perf_counter()
    result = wait_for_events(eventful, poll_s=poll_s)
    event_wall = time.perf_counter() - t0
    assert result.ok and len(result.states) == n_jobs
    ratio = poll_snapshots / max(1, eventful.calls)
    print(f"  waitjobs over {n_jobs} jobs: polling {poll_snapshots} snapshots "
          f"({poll_wall:.2f}s) vs events {eventful.calls} ({event_wall:.2f}s) "
          f"→ {ratio:.0f}x fewer")
    return {
        "jobs": n_jobs,
        "poll_snapshots": poll_snapshots,
        "event_snapshots": eventful.calls,
        "snapshot_ratio": ratio,
        "poll_wall_s": poll_wall,
        "event_wall_s": event_wall,
    }


def bench_dispatch(n_events: int = 20000, n_subscribers: int = 4) -> dict:
    """Raw bus throughput vs the cost of diffing one 1,000-row snapshot."""
    bus = EventBus()
    sink = [0]

    def sub(e):
        sink[0] += 1

    for i in range(n_subscribers):
        bus.subscribe(sub, types=TERMINAL_EVENTS if i % 2 else None)
    events = [
        JobEvent(type="COMPLETED" if i % 3 else "STARTED", jobid=str(i), at=T0)
        for i in range(n_events)
    ]
    t0 = time.perf_counter()
    for e in events:
        bus.emit(e)
    emit_wall = time.perf_counter() - t0
    rate = n_events / max(emit_wall, 1e-9)

    # one poll of a 1,000-job queue, as the adapter would pay it
    rows = {
        str(i): {"jobid": str(i), "name": f"j{i}", "user": "u",
                 "state": "RUNNING", "reason": "", "nodelist": "n0"}
        for i in range(1000)
    }
    moved = dict(rows)
    for i in range(0, 1000, 2):  # half the queue churns between polls
        moved[str(i)] = dict(rows[str(i)], state="PENDING")
    t0 = time.perf_counter()
    n_diffs = 20
    for _ in range(n_diffs):
        diff_snapshots(rows, moved, T0)
    diff_wall = (time.perf_counter() - t0) / n_diffs
    print(f"  bus: {rate:,.0f} events/s through {n_subscribers} subscribers; "
          f"one 1k-row snapshot diff {diff_wall * 1e3:.1f} ms "
          f"(≈{rate * diff_wall:,.0f} events)")
    return {
        "events_per_s": rate,
        "subscribers": n_subscribers,
        "snapshot_diff_ms": diff_wall * 1e3,
        "events_per_diff": rate * diff_wall,
    }


def bench_eco_hold_release(n_eco: int = 200) -> dict:
    """Held eco jobs across a simulated day: never later than the static
    begin; early when observed load allows."""
    sched = EcoScheduler(
        weekday_windows=[(0, 360), (720, 780)],
        weekend_windows=[(0, 420), (660, 960)],
        peak_hours=[(1020, 1200)],
        horizon_days=14,
        min_delay_s=0,
    )
    sim = SimCluster(nodes=[SimNode(f"n{i:03d}", cpus=64) for i in range(16)],
                     now=T0)
    controller = EcoController(sim, sched)
    # a morning of base load that drains by mid-day → room for early release
    base = [
        Job(name=f"base-{i}", command="true",
            opts=Opts.new(threads=8, memory="2GB", time="8h"),
            sim_duration_s=3600 + (i % 16) * 900)
        for i in range(120)
    ]
    sim.submit_many(base)
    statics: dict[str, datetime] = {}
    deferred = 0
    for i in range(n_eco):
        hours = 1 + (i % 6)
        job = Job(name=f"eco-{i}", command="true",
                  opts=Opts.new(threads=2, memory="1GB", time=f"{hours}h"),
                  sim_duration_s=900 + (i % 8) * 450)
        dec = sched.next_window(hours * 3600, T0)
        jid = controller.submit(job, now=T0)
        if dec.deferred:
            deferred += 1
            statics[str(jid)] = dec.begin
    sim.advance(to=T0 + timedelta(days=2))
    late = 0
    for jid, begin in statics.items():
        j = sim.get(jid)
        assert j is not None and j.started_at is not None, jid
        if j.started_at > begin:
            late += 1
    early = [r for r in controller.released if r.early]
    mean_lead_h = (
        sum(r.lead_s for r in early) / len(early) / 3600 if early else 0.0
    )
    print(f"  eco v2: {deferred}/{n_eco} deferred→held, "
          f"{len(early)} released early (mean lead {mean_lead_h:.1f} h), "
          f"{late} late vs static begin (must be 0)")
    return {
        "eco_jobs": n_eco,
        "deferred": deferred,
        "released_early": len(early),
        "mean_early_lead_h": mean_lead_h,
        "late_vs_static": late,
    }


def run() -> dict:
    out = {
        "waitjobs": bench_waitjobs_snapshots(),
        "dispatch": bench_dispatch(),
        "eco_hold_release": bench_eco_hold_release(),
    }
    assert out["waitjobs"]["snapshot_ratio"] >= 10, "acceptance: ≥10x fewer"
    assert out["eco_hold_release"]["late_vs_static"] == 0
    return out


if __name__ == "__main__":
    import json
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
    print(json.dumps(run(), indent=1))
