"""Submission-path benchmark (paper §Statement of Need: reduced boilerplate).

Measures (1) the boilerplate reduction — characters/directives a user types
with runjob vs the raw sbatch script the system generates for them — and
(2) end-to-end submission throughput against the simulator (script gen +
scheduling decision + queue insert), which bounds how fast array-heavy
pipelines can submit.
"""

from __future__ import annotations

import time

from repro.core import Job, Opts, SimCluster, SubmitEngine


def boilerplate_reduction() -> dict:
    user_cmd = (
        'runjob -n assembly -c 18 -m 64 -t 12 -w ./logs/ '
        '"flye --nano-raw reads.fastq --out-dir asm"'
    )
    job = Job(
        name="assembly",
        command="flye --nano-raw reads.fastq --out-dir asm",
        opts=Opts.new(threads=18, memory="64GB", time=12, output_dir="./logs/"),
    )
    script = job.script()
    directives = sum(1 for ln in script.splitlines() if ln.startswith("#SBATCH"))
    return {
        "user_chars": len(user_cmd),
        "generated_chars": len(script),
        "generated_directives": directives,
        "reduction_factor": round(len(script) / len(user_cmd), 2),
    }


def submission_throughput(n: int = 300) -> dict:
    sim = SimCluster()
    opts = Opts.new(threads=2, memory="2GB", time="1h")
    t0 = time.perf_counter()
    for i in range(n):
        Job(name=f"j{i}", command="true", opts=opts, sim_duration_s=60).run(sim)
    dt = time.perf_counter() - t0
    return {"jobs": n, "jobs_per_s": n / dt, "mean_ms": dt / n * 1e3}


def array_submission(n_files: int = 500) -> dict:
    sim = SimCluster()
    t0 = time.perf_counter()
    Job(
        name="arr", command="process #FILE#",
        opts=Opts.new(threads=1, memory="1GB", time="1h"),
        files=[f"s{i}.fq" for i in range(n_files)],
        sim_duration_s=60,
    ).run(sim)
    dt = time.perf_counter() - t0
    return {"array_tasks": n_files, "submit_ms": dt * 1e3}


def _homogeneous_jobs(n: int) -> list[Job]:
    return [
        Job(name=f"j{i}", command=f"process sample_{i}.fq",
            opts=Opts.new(threads=2, memory="2GB", time="1h"),
            sim_duration_s=60)
        for i in range(n)
    ]


def engine_vs_loop(n: int = 1000) -> dict:
    """Batch-vs-loop: SubmitEngine array coalescing against per-job run()."""
    # baseline: N independent Job.run() calls (script write + submit each)
    sim_loop = SimCluster()
    loop_jobs = _homogeneous_jobs(n)
    t0 = time.perf_counter()
    for job in loop_jobs:
        job.run(sim_loop)
    t_loop = time.perf_counter() - t0

    # engine: the same N jobs coalesced into one job array (one submission)
    sim_engine = SimCluster()
    engine_jobs = _homogeneous_jobs(n)
    t0 = time.perf_counter()
    result = SubmitEngine(sim_engine).submit_many(engine_jobs)
    t_engine = time.perf_counter() - t0

    assert result.sbatch_calls == 1 and len(result) == n
    return {
        "jobs": n,
        "loop_s": t_loop,
        "engine_s": t_engine,
        "loop_jobs_per_s": n / t_loop,
        "engine_jobs_per_s": n / t_engine,
        "speedup": t_loop / t_engine,
        "sbatch_calls": result.sbatch_calls,
    }


def run() -> dict:
    out = {
        "boilerplate": boilerplate_reduction(),
        "throughput": submission_throughput(),
        "array": array_submission(),
        "engine": engine_vs_loop(),
    }
    b = out["boilerplate"]
    print(f"  boilerplate: {b['user_chars']} user chars → "
          f"{b['generated_chars']} script chars "
          f"({b['generated_directives']} #SBATCH directives), "
          f"{b['reduction_factor']}× generated")
    print(f"  submission: {out['throughput']['jobs_per_s']:.0f} jobs/s "
          f"({out['throughput']['mean_ms']:.2f} ms each)")
    print(f"  500-task array submit: {out['array']['submit_ms']:.1f} ms")
    e = out["engine"]
    print(f"  engine batch ({e['jobs']} homogeneous jobs): "
          f"loop {e['loop_jobs_per_s']:.0f} jobs/s → "
          f"engine {e['engine_jobs_per_s']:.0f} jobs/s "
          f"({e['speedup']:.1f}× via array coalescing, "
          f"{e['sbatch_calls']} submission)")
    return out
