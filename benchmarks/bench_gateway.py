"""Gateway daemon benchmark — poll amplification + the read storm, gated in CI.

**Poll amplification.** Eight concurrent clients monitor a simulated day,
submitting batches as it unfolds. Two deployments of the *same* workload:

* **direct** — 8 independent CLI processes, modelled as 8 per-process
  :class:`QueueCache`\\ s over the same cluster whose TTL has lapsed by
  the next monitoring tick (what independent ``lsjobs`` loops do): every
  tick costs 8 real ``backend.queue()`` polls.
* **daemon** — one :class:`GatewayServer` owns the only QueueCache; the
  8 clients make the same reads as Unix-socket RPCs and share its single
  snapshot: every tick costs 1 real poll.

The headline invariant (``check_bench.py`` fails CI when false): the
daemon takes **>= 8x fewer** backend polls, and the cluster ends the day
in an identical state — same job ids, same names, same final states —
so the dedup is free, not a behaviour change.

**Read storm.** 100k pending jobs (``NBI_BENCH_STORM_JOBS``), 16 watchers
hammering the ``queue`` RPC. The PR-9 read path (re-pinned here as
:class:`_LegacyServer`: thread-per-connection, every request JSON-encodes
the full snapshot under the backend lock) against the v2 daemon (shared
per-generation frames, filter pushdown, delta protocol). Gated:
``throughput_ratio_ok`` (>=10x queue RPCs/s), ``filtered_bytes_ratio_ok``
(>=20x fewer wire bytes per poll for a per-user watcher), the latency
invariant (v2 p99 below legacy p50), and row-identity between the two
protocols on the same snapshot.
"""

from __future__ import annotations

import json as _json
import os
import socket as _socket
import struct as _struct
import tempfile
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

from repro.cli.session import GatewayClient
from repro.core import Job, Opts, SimCluster, SimNode
from repro.core.engine import QueueCache, SubmitEngine
from repro.core.gateway import GatewayServer, recv_frame

N_CLIENTS = 8
BATCHES = 16  # one batch submitted per tick until exhausted
JOBS_PER_BATCH = 5
TICK_S = 120.0

STORM_JOBS = int(os.environ.get("NBI_BENCH_STORM_JOBS", "100000"))
STORM_WATCHERS = 16
STORM_USERS = 32
STORM_LEGACY_POLLS = 2  # per watcher: each one re-encodes the snapshot
STORM_POLLS = 40  # per watcher against the v2 daemon (deltas make it cheap)


class _CountingBackend:
    """Proxy over the simulator counting real ``queue()`` polls."""

    def __init__(self, sim: SimCluster):
        self.sim = sim
        self.calls = 0

    def queue(self):
        self.calls += 1
        return self.sim.queue()

    def __getattr__(self, name):
        return getattr(self.sim, name)


def _batch_jobs(batch: int) -> list[Job]:
    jobs = []
    for slot in range(JOBS_PER_BATCH):
        k = batch * JOBS_PER_BATCH + slot
        jobs.append(Job(
            name=f"day-{k:03d}", command="true",
            opts=Opts.new(threads=2, memory="2GB", time="2h"),
            sim_duration_s=180 + (k % 12) * 120,
        ))
    return jobs


def _drive(submit, advance, read_all) -> int:
    """One simulated day: submit while batches remain, tick, everyone
    reads. Returns the tick count (identical across modes by design)."""
    ticks = 0
    batch = 0
    while True:
        if batch < BATCHES:
            submit(batch % N_CLIENTS, _batch_jobs(batch))
            batch += 1
        advance(TICK_S)
        rows = read_all()
        ticks += 1
        if batch >= BATCHES and not rows:
            return ticks
        if ticks > 500:
            raise RuntimeError("workload failed to drain")


def _outcome(sim: SimCluster) -> list:
    return sorted((jid, j.name, j.state) for jid, j in sim.jobs.items())


def run_direct() -> dict:
    sim = SimCluster()
    counted = _CountingBackend(sim)
    # ttl 0: by the next tick every independent process's snapshot has
    # lapsed — each of the 8 re-polls, which is the deployment being fixed
    caches = [QueueCache(counted, ttl_s=0.0) for _ in range(N_CLIENTS)]

    def submit(client: int, jobs: list[Job]):
        SubmitEngine(caches[client], coalesce=True).submit_many(jobs)

    def read_all():
        rows = [c.queue() for c in caches]
        return rows[0]

    ticks = _drive(submit, lambda s: counted.advance(s), read_all)
    return {"ticks": ticks, "polls": counted.calls, "outcome": _outcome(sim)}


def run_daemon() -> dict:
    sim = SimCluster()
    counted = _CountingBackend(sim)
    sock = str(Path(tempfile.mkdtemp(prefix="nbi-bench-gw-")) / "gw.sock")
    server = GatewayServer(counted, sock, ttl_s=3600.0, eco=False,
                           rate=1e9, burst=1e9)
    server.start()
    clients = [GatewayClient(sock, user=f"user{i}") for i in range(N_CLIENTS)]
    rpcs = 0
    try:
        with ThreadPoolExecutor(max_workers=N_CLIENTS) as pool:

            def submit(client: int, jobs: list[Job]):
                clients[client].submit_batch(jobs, eco=False, coalesce=True)

            def read_all():
                nonlocal rpcs
                rows = list(pool.map(lambda c: c.queue(), clients))
                rpcs += N_CLIENTS
                return rows[0]

            t0 = time.perf_counter()
            ticks = _drive(submit, lambda s: clients[0].advance(s), read_all)
            wall = time.perf_counter() - t0
    finally:
        server.close()
    return {
        "ticks": ticks,
        "polls": counted.calls,
        "outcome": _outcome(sim),
        "queue_rpcs": rpcs,
        "wall_s": wall,
        "throttled": server.throttled,
    }


# ---------------------------------------------------------------------------
# Read storm
# ---------------------------------------------------------------------------


class _LegacyServer:
    """The PR-9 gateway read path, pinned as the storm baseline.

    Thread-per-connection; every ``queue`` RPC takes the backend lock and
    ``json.dumps`` the full snapshot from scratch. This is what the
    shared-frame encoder replaced — keeping it here (not importing the
    production class) pins the baseline even as the real server evolves.
    """

    _LEN = _struct.Struct(">I")

    def __init__(self, cache: QueueCache, socket_path: str):
        self.cache = cache
        self.socket_path = socket_path
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._listener: "_socket.socket | None" = None

    def start(self) -> None:
        listener = _socket.socket(_socket.AF_UNIX, _socket.SOCK_STREAM)
        listener.bind(self.socket_path)
        listener.listen(64)
        listener.settimeout(0.2)
        self._listener = listener
        threading.Thread(target=self._accept_loop, daemon=True).start()

    def close(self) -> None:
        self._stop.set()
        if self._listener is not None:
            self._listener.close()
        if os.path.exists(self.socket_path):
            os.unlink(self.socket_path)

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except _socket.timeout:
                continue
            except OSError:
                return
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: _socket.socket) -> None:
        try:
            while not self._stop.is_set():
                req = recv_frame(conn)
                if req is None:
                    return
                rid = req.get("id") if isinstance(req, dict) else None
                method = (req or {}).get("method", "")
                if method == "queue":
                    with self._lock:
                        rows = self.cache.queue()
                    result = rows
                elif method == "ping":
                    result = {"pong": True}
                else:
                    result = None
                payload = _json.dumps(
                    {"id": rid, "ok": True, "result": result},
                    separators=(",", ":"), default=str,
                ).encode("utf-8")
                conn.sendall(self._LEN.pack(len(payload)) + payload)
        except OSError:
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass


class _CountingSocket:
    """Socket proxy counting bytes in both directions."""

    def __init__(self, sock: _socket.socket, counter: dict):
        self._sock = sock
        self._counter = counter

    def recv(self, n: int) -> bytes:
        data = self._sock.recv(n)
        self._counter["rx"] += len(data)
        return data

    def sendall(self, data) -> None:
        self._counter["tx"] += len(data)
        return self._sock.sendall(data)

    def __getattr__(self, name):
        return getattr(self._sock, name)


class _MeteredClient(GatewayClient):
    """GatewayClient that meters wire bytes and per-RPC latency."""

    def __init__(self, *args, **kwargs):
        self.bytes = {"rx": 0, "tx": 0}
        self.latencies_s: list = []
        super().__init__(*args, **kwargs)

    def _connect(self, timeout_s):
        return _CountingSocket(super()._connect(timeout_s), self.bytes)

    def _call(self, method, **kwargs):
        t0 = time.perf_counter()
        try:
            return super()._call(method, **kwargs)
        finally:
            self.latencies_s.append(time.perf_counter() - t0)


def _percentile(values: list, pct: float) -> float:
    if not values:
        return 0.0
    ordered = sorted(values)
    idx = min(len(ordered) - 1, int(round(pct / 100.0 * (len(ordered) - 1))))
    return ordered[idx]


def _storm_cluster() -> SimCluster:
    """STORM_JOBS long-running jobs across STORM_USERS users, nearly all
    pending (tiny cluster): the 100k-row snapshot every watcher polls."""
    from datetime import datetime

    sim = SimCluster(
        nodes=[SimNode(f"n{i:02d}", cpus=64, memory_mb=262144)
               for i in range(8)],
        now=datetime(2026, 3, 18, 8, 0, 0), default_user="bench",
    )
    per_user = max(1, STORM_JOBS // STORM_USERS)
    submitted = 0
    for u in range(STORM_USERS):
        n = per_user if u < STORM_USERS - 1 else STORM_JOBS - submitted
        sim.default_user = f"u{u:02d}"
        sim.submit_many([
            Job(name=f"storm-{u:02d}-{i}", command="true",
                opts=Opts(threads=2, memory_mb=2048, time_s=14400),
                sim_duration_s=7200)
            for i in range(n)
        ])
        submitted += n
    sim.default_user = "bench"
    return sim


def run_storm() -> dict:
    sim = _storm_cluster()
    tmp = Path(tempfile.mkdtemp(prefix="nbi-bench-storm-"))

    # -- legacy baseline: every RPC re-encodes the full snapshot -----------
    legacy_cache = QueueCache(sim, ttl_s=3600.0)
    legacy = _LegacyServer(legacy_cache, str(tmp / "legacy.sock"))
    legacy.start()
    legacy_watchers = [
        _MeteredClient(legacy.socket_path, user=f"w{i:02d}")
        for i in range(STORM_WATCHERS)
    ]
    legacy_rows: list = []

    def _legacy_poll(client):
        rows = client._call("queue")  # the v1 request shape, verbatim
        if not legacy_rows:
            legacy_rows.append(rows)

    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=STORM_WATCHERS) as pool:
        list(pool.map(
            lambda c: [_legacy_poll(c) for _ in range(STORM_LEGACY_POLLS)],
            legacy_watchers,
        ))
    legacy_wall = time.perf_counter() - t0
    legacy.close()
    legacy_cache.unbind_bus()
    legacy_polls = STORM_WATCHERS * STORM_LEGACY_POLLS
    legacy_rx = sum(c.bytes["rx"] for c in legacy_watchers)
    legacy_lat = [lat for c in legacy_watchers for lat in c.latencies_s]

    # -- v2 daemon: shared frames, pushdown, deltas ------------------------
    server = GatewayServer(sim, str(tmp / "gw.sock"), ttl_s=3600.0,
                           eco=False, rate=1e9, burst=1e9)
    server.start()
    # half the watchers read everything (delta protocol), half watch one
    # user's jobs (filter pushdown + deltas)
    full_watchers = [
        _MeteredClient(server.socket_path, user=f"w{i:02d}")
        for i in range(STORM_WATCHERS // 2)
    ]
    user_watchers = [
        _MeteredClient(server.socket_path, user=f"w{i:02d}")
        for i in range(STORM_WATCHERS // 2)
    ]
    v2_rows: list = []
    filtered_counts: list = []

    def _v2_poll(idx_client):
        idx, client = idx_client
        rows = client.queue()
        if not v2_rows:
            v2_rows.append(rows)

    def _filtered_poll(idx_client):
        idx, client = idx_client
        rows = client.queue_filtered(user=f"u{idx % STORM_USERS:02d}")
        filtered_counts.append(len(rows))

    half = STORM_POLLS // 2
    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=STORM_WATCHERS) as pool:
        for rounds in (half, STORM_POLLS - half):
            list(pool.map(
                lambda ic: [_v2_poll(ic) for _ in range(rounds)],
                enumerate(full_watchers),
            ))
            list(pool.map(
                lambda ic: [_filtered_poll(ic) for _ in range(rounds)],
                enumerate(user_watchers),
            ))
            # a burst of real cluster motion between the halves: deltas,
            # not snapshots, should carry it to the watchers
            server.cache.advance(60)
    v2_wall = time.perf_counter() - t0
    snap_stats = server.snapshots.stats()
    server.close()
    v2_polls = STORM_WATCHERS * STORM_POLLS
    v2_lat = ([lat for c in full_watchers for lat in c.latencies_s]
              + [lat for c in user_watchers for lat in c.latencies_s])
    filtered_rx = sum(c.bytes["rx"] for c in user_watchers)
    filtered_polls = len(user_watchers) * STORM_POLLS

    legacy_rps = legacy_polls / max(legacy_wall, 1e-9)
    v2_rps = v2_polls / max(v2_wall, 1e-9)
    throughput_ratio = v2_rps / max(legacy_rps, 1e-9)
    legacy_bpp = legacy_rx / max(legacy_polls, 1)
    filtered_bpp = filtered_rx / max(filtered_polls, 1)
    bytes_ratio = legacy_bpp / max(filtered_bpp, 1e-9)
    legacy_p50 = _percentile(legacy_lat, 50) * 1e3
    legacy_p99 = _percentile(legacy_lat, 99) * 1e3
    v2_p50 = _percentile(v2_lat, 50) * 1e3
    v2_p99 = _percentile(v2_lat, 99) * 1e3

    def _keyed(rows):
        return sorted((r["jobid"], r["name"], r["state"]) for r in rows)

    rows_identical = bool(legacy_rows and v2_rows
                          and _keyed(legacy_rows[0]) == _keyed(v2_rows[0]))
    out = {
        "jobs": STORM_JOBS,
        "watchers": STORM_WATCHERS,
        "legacy_polls": legacy_polls,
        "legacy_wall_s": legacy_wall,
        "legacy_queue_rps": legacy_rps,
        "legacy_bytes_per_poll": legacy_bpp,
        "legacy_p50_ms": legacy_p50,
        "legacy_p99_ms": legacy_p99,
        "storm_polls": v2_polls,
        "storm_wall_s": v2_wall,
        "storm_queue_rps": v2_rps,
        "storm_p50_ms": v2_p50,
        "storm_p99_ms": v2_p99,
        "filtered_bytes_per_poll": filtered_bpp,
        "throughput_ratio_x": throughput_ratio,
        "throughput_ratio_ok": throughput_ratio >= 10.0,
        "filtered_bytes_ratio_x": bytes_ratio,
        "filtered_bytes_ratio_ok": bytes_ratio >= 20.0,
        # relative latency gate (absolute ms are CI-runner noise): the v2
        # tail must stay below the legacy *median*
        "latency_ok": v2_p99 <= legacy_p50,
        "rows_identical": rows_identical,
        "filtered_rows_seen": max(filtered_counts) if filtered_counts else 0,
        "snapshot_encodes": snap_stats["encodes"],
        "delta_hits": snap_stats["delta_hits"],
        "unchanged_hits": snap_stats["unchanged_hits"],
    }
    print(f"  storm: {STORM_JOBS} jobs x {STORM_WATCHERS} watchers | "
          f"queue rps {legacy_rps:.1f} -> {v2_rps:.0f} "
          f"({throughput_ratio:.0f}x, ok={out['throughput_ratio_ok']})")
    print(f"  wire bytes/poll: legacy {legacy_bpp / 1e6:.2f} MB -> filtered "
          f"{filtered_bpp / 1e3:.1f} kB ({bytes_ratio:.0f}x fewer, "
          f"ok={out['filtered_bytes_ratio_ok']})")
    print(f"  latency ms: legacy p50/p99 {legacy_p50:.1f}/{legacy_p99:.1f} "
          f"-> v2 {v2_p50:.2f}/{v2_p99:.2f} | encodes "
          f"{snap_stats['encodes']}, deltas {snap_stats['delta_hits']}, "
          f"unchanged {snap_stats['unchanged_hits']}")
    return out


def run() -> dict:
    direct = run_direct()
    daemon = run_daemon()
    storm = run_storm()
    amplification = direct["polls"] / max(1, daemon["polls"])
    out = {
        "clients": N_CLIENTS,
        "jobs": BATCHES * JOBS_PER_BATCH,
        "ticks": daemon["ticks"],
        "direct_polls": direct["polls"],
        "daemon_polls": daemon["polls"],
        "poll_amplification_x": amplification,
        "poll_amplification_ok": (
            amplification >= float(N_CLIENTS)
            and direct["ticks"] == daemon["ticks"]
        ),
        "outcomes_identical": direct["outcome"] == daemon["outcome"],
        "daemon_queue_rpcs": daemon["queue_rpcs"],
        "daemon_wall_s": daemon["wall_s"],
        "daemon_queue_rps": daemon["queue_rpcs"] / max(daemon["wall_s"], 1e-9),
        "daemon_throttled": daemon["throttled"],
        "storm": storm,
    }
    print(f"  {out['jobs']} jobs over {out['ticks']} ticks x "
          f"{N_CLIENTS} clients")
    print(f"  backend polls: direct {out['direct_polls']} -> daemon "
          f"{out['daemon_polls']} ({amplification:.1f}x fewer; "
          f"outcomes identical: {out['outcomes_identical']})")
    print(f"  daemon served {out['daemon_queue_rpcs']} queue RPCs in "
          f"{out['daemon_wall_s']:.2f}s ({out['daemon_queue_rps']:.0f} rps)")
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1, default=str))
