"""Gateway daemon benchmark — the poll-amplification claim, gated in CI.

Eight concurrent clients monitor a simulated day, submitting batches as
it unfolds. Two deployments of the *same* workload:

* **direct** — 8 independent CLI processes, modelled as 8 per-process
  :class:`QueueCache`\\ s over the same cluster whose TTL has lapsed by
  the next monitoring tick (what independent ``lsjobs`` loops do): every
  tick costs 8 real ``backend.queue()`` polls.
* **daemon** — one :class:`GatewayServer` owns the only QueueCache; the
  8 clients make the same reads as Unix-socket RPCs and share its single
  snapshot: every tick costs 1 real poll.

The headline invariant (``check_bench.py`` fails CI when false): the
daemon takes **>= 8x fewer** backend polls, and the cluster ends the day
in an identical state — same job ids, same names, same final states —
so the dedup is free, not a behaviour change.
"""

from __future__ import annotations

import tempfile
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

from repro.cli.session import GatewayClient
from repro.core import Job, Opts, SimCluster
from repro.core.engine import QueueCache, SubmitEngine
from repro.core.gateway import GatewayServer

N_CLIENTS = 8
BATCHES = 16  # one batch submitted per tick until exhausted
JOBS_PER_BATCH = 5
TICK_S = 120.0


class _CountingBackend:
    """Proxy over the simulator counting real ``queue()`` polls."""

    def __init__(self, sim: SimCluster):
        self.sim = sim
        self.calls = 0

    def queue(self):
        self.calls += 1
        return self.sim.queue()

    def __getattr__(self, name):
        return getattr(self.sim, name)


def _batch_jobs(batch: int) -> list[Job]:
    jobs = []
    for slot in range(JOBS_PER_BATCH):
        k = batch * JOBS_PER_BATCH + slot
        jobs.append(Job(
            name=f"day-{k:03d}", command="true",
            opts=Opts.new(threads=2, memory="2GB", time="2h"),
            sim_duration_s=180 + (k % 12) * 120,
        ))
    return jobs


def _drive(submit, advance, read_all) -> int:
    """One simulated day: submit while batches remain, tick, everyone
    reads. Returns the tick count (identical across modes by design)."""
    ticks = 0
    batch = 0
    while True:
        if batch < BATCHES:
            submit(batch % N_CLIENTS, _batch_jobs(batch))
            batch += 1
        advance(TICK_S)
        rows = read_all()
        ticks += 1
        if batch >= BATCHES and not rows:
            return ticks
        if ticks > 500:
            raise RuntimeError("workload failed to drain")


def _outcome(sim: SimCluster) -> list:
    return sorted((jid, j.name, j.state) for jid, j in sim.jobs.items())


def run_direct() -> dict:
    sim = SimCluster()
    counted = _CountingBackend(sim)
    # ttl 0: by the next tick every independent process's snapshot has
    # lapsed — each of the 8 re-polls, which is the deployment being fixed
    caches = [QueueCache(counted, ttl_s=0.0) for _ in range(N_CLIENTS)]

    def submit(client: int, jobs: list[Job]):
        SubmitEngine(caches[client], coalesce=True).submit_many(jobs)

    def read_all():
        rows = [c.queue() for c in caches]
        return rows[0]

    ticks = _drive(submit, lambda s: counted.advance(s), read_all)
    return {"ticks": ticks, "polls": counted.calls, "outcome": _outcome(sim)}


def run_daemon() -> dict:
    sim = SimCluster()
    counted = _CountingBackend(sim)
    sock = str(Path(tempfile.mkdtemp(prefix="nbi-bench-gw-")) / "gw.sock")
    server = GatewayServer(counted, sock, ttl_s=3600.0, eco=False,
                           rate=1e9, burst=1e9)
    server.start()
    clients = [GatewayClient(sock, user=f"user{i}") for i in range(N_CLIENTS)]
    rpcs = 0
    try:
        with ThreadPoolExecutor(max_workers=N_CLIENTS) as pool:

            def submit(client: int, jobs: list[Job]):
                clients[client].submit_batch(jobs, eco=False, coalesce=True)

            def read_all():
                nonlocal rpcs
                rows = list(pool.map(lambda c: c.queue(), clients))
                rpcs += N_CLIENTS
                return rows[0]

            t0 = time.perf_counter()
            ticks = _drive(submit, lambda s: clients[0].advance(s), read_all)
            wall = time.perf_counter() - t0
    finally:
        server.close()
    return {
        "ticks": ticks,
        "polls": counted.calls,
        "outcome": _outcome(sim),
        "queue_rpcs": rpcs,
        "wall_s": wall,
        "throttled": server.throttled,
    }


def run() -> dict:
    direct = run_direct()
    daemon = run_daemon()
    amplification = direct["polls"] / max(1, daemon["polls"])
    out = {
        "clients": N_CLIENTS,
        "jobs": BATCHES * JOBS_PER_BATCH,
        "ticks": daemon["ticks"],
        "direct_polls": direct["polls"],
        "daemon_polls": daemon["polls"],
        "poll_amplification_x": amplification,
        "poll_amplification_ok": (
            amplification >= float(N_CLIENTS)
            and direct["ticks"] == daemon["ticks"]
        ),
        "outcomes_identical": direct["outcome"] == daemon["outcome"],
        "daemon_queue_rpcs": daemon["queue_rpcs"],
        "daemon_wall_s": daemon["wall_s"],
        "daemon_queue_rps": daemon["queue_rpcs"] / max(daemon["wall_s"], 1e-9),
        "daemon_throttled": daemon["throttled"],
    }
    print(f"  {out['jobs']} jobs over {out['ticks']} ticks x "
          f"{N_CLIENTS} clients")
    print(f"  backend polls: direct {out['direct_polls']} -> daemon "
          f"{out['daemon_polls']} ({amplification:.1f}x fewer; "
          f"outcomes identical: {out['outcomes_identical']})")
    print(f"  daemon served {out['daemon_queue_rpcs']} queue RPCs in "
          f"{out['daemon_wall_s']:.2f}s ({out['daemon_queue_rps']:.0f} rps)")
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1, default=str))
