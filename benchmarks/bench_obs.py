"""Observability benchmark — what does watching the stack cost?

Runs the federated simulated day (``bench_federation.simulated_day``,
``NBI_BENCH_DAY_JOBS`` jobs; CI uses 20,000) twice:

1. **no-op**: the default :class:`~repro.obs.metrics.NullRegistry` active —
   every instrumentation site pays its disabled-path cost (a couple of
   attribute lookups per batch/poll). This is the rate the trajectory
   gates against the pre-obs baseline.
2. **instrumented**: a real :class:`MetricsRegistry` enabled AND a
   :class:`~repro.obs.trace.JobTracer` subscribed to the federation bus —
   every event folds into a span, every batch/poll records.

Headlines:

* ``overhead_pct`` — instrumented vs no-op wall time; the acceptance gate
  is ≤5% (published as the ``overhead_ok`` invariant);
* ``span_conservation`` — spans finalized by the tracer == jobs archived
  by accounting == jobs submitted (tracing extends the conservation law);
* the instrumented run's snapshot is persisted to
  ``results/obs_day.json`` + ``results/obs_day.prom`` so CI can render it
  with ``nbimon --json --snapshot`` and validate the exposition file with
  ``nbimon --check-textfile``.
"""

from __future__ import annotations

import os
from pathlib import Path

from repro.obs import metrics as obs_metrics
from repro.obs.export import parse_textfile, write_snapshot, write_textfile
from repro.obs.trace import JobTracer

from .bench_federation import simulated_day

RESULTS_DIR = Path(__file__).resolve().parent.parent / "results"
SNAPSHOT_PATH = RESULTS_DIR / "obs_day.json"
TEXTFILE_PATH = RESULTS_DIR / "obs_day.prom"

#: acceptance ceiling: a fully traced day may cost at most this much
OVERHEAD_LIMIT_PCT = 5.0

#: alternating noop/instrumented repeats; best-of-N filters scheduler noise
#: (single runs on shared runners swing ±30%, far beyond the 5% gate)
REPEATS = max(1, int(os.environ.get("NBI_BENCH_OBS_REPEATS", "3")))


def _instrumented_day() -> "tuple[dict, JobTracer, object]":
    """One simulated day with a fresh registry + tracer on the bus."""
    reg = obs_metrics.enable(obs_metrics.MetricsRegistry())
    hooked: dict = {}

    def on_backend(fed):
        tracer = JobTracer(keep=64)
        tracer.attach(fed.bus)
        hooked["tracer"] = tracer
        return tracer.detach

    try:
        inst = simulated_day(on_backend=on_backend)
    finally:
        obs_metrics.disable()
    return inst, hooked["tracer"], reg


def run() -> dict:
    out: dict = {}

    # -- 1. alternating repeats; best wall time on each side ------------------
    obs_metrics.disable()
    simulated_day()  # warmup: JIT-free, but page cache + allocator settle
    noop = inst = tracer = reg = None
    for _ in range(REPEATS):
        obs_metrics.disable()
        n = simulated_day()
        if noop is None or n["wall_s"] < noop["wall_s"]:
            noop = n
        i, t, r = _instrumented_day()
        if inst is None or i["wall_s"] < inst["wall_s"]:
            inst = i
        tracer, reg = t, r  # conservation + snapshot come from the LAST run

    out["noop"] = {k: noop[k] for k in ("jobs", "wall_s", "day_jobs_per_s")}
    out["noop_day_jobs_per_s"] = noop["day_jobs_per_s"]

    # -- 2. persist the last instrumented run's registry ----------------------
    snap = write_snapshot(SNAPSHOT_PATH, reg, meta={
        "benchmark": "obs.simulated_day",
        "jobs": inst["jobs"],
        "spans_finished": tracer.finished,
        "spans_open": len(tracer.open),
        "archived": inst["archived"],
        "outcomes": dict(sorted(tracer.outcomes.items())),
    })
    text = write_textfile(TEXTFILE_PATH, snap=snap)
    parse_textfile(text)  # the exporter must emit what it can parse

    out["instrumented"] = {
        k: inst[k] for k in ("jobs", "wall_s", "day_jobs_per_s")
    }
    out["instrumented_day_jobs_per_s"] = inst["day_jobs_per_s"]
    out["repeats"] = REPEATS
    out["overhead_pct"] = (
        100.0 * (inst["wall_s"] - noop["wall_s"]) / noop["wall_s"]
        if noop["wall_s"] else 0.0
    )
    out["overhead_ok"] = out["overhead_pct"] <= OVERHEAD_LIMIT_PCT

    # -- 3. trace conservation: every job became exactly one finished span ----
    out["spans_finished"] = tracer.finished
    out["spans_open"] = len(tracer.open)
    out["archived"] = inst["archived"]
    out["span_conservation"] = (
        tracer.finished == inst["archived"] == inst["jobs"]
        and len(tracer.open) == 0
        and inst["conserved"]
    )
    out["metric_families"] = len(snap["metrics"])
    out["snapshot_path"] = str(SNAPSHOT_PATH)
    out["textfile_path"] = str(TEXTFILE_PATH)

    print(f"  obs: no-op {noop['wall_s']:.1f}s vs instrumented "
          f"{inst['wall_s']:.1f}s → overhead {out['overhead_pct']:+.1f}% "
          f"(limit {OVERHEAD_LIMIT_PCT:.0f}%) | spans {tracer.finished}"
          f"/{inst['jobs']} conserved={out['span_conservation']} | "
          f"{out['metric_families']} metric families")
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
