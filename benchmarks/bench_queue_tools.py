"""Queue-tool benchmark (paper Figure 1 / §lsjobs-viewjobs).

A 2,000-job simulated cluster: time lsjobs table rendering, viewjobs
ViewModel refresh + full interaction script, whojobs aggregation — the
tools must stay interactive on production-sized queues.
"""

from __future__ import annotations

import time

from repro.cli.lsjobs import HEADERS, queue_rows
from repro.cli.render import render_table
from repro.cli.viewjobs import ViewModel
from repro.cli.whojobs import utilisation_rows
from repro.core import Job, Opts, Queue, QueueCache, SimCluster, SimNode


def big_sim(n_jobs: int = 2000) -> SimCluster:
    sim = SimCluster(nodes=[SimNode(f"n{i:03d}", cpus=128) for i in range(64)])
    opts = Opts.new(threads=2, memory="2GB", time="10h")
    for i in range(n_jobs):
        j = Job(name=f"task-{i % 37}", command="true", opts=opts,
                sim_duration_s=36000)
        jid = j.run(sim)
        sim.get(jid).user = f"user{i % 23}"
    return sim


class _CountingBackend:
    """Wraps a backend, counting real queue() polls."""

    def __init__(self, inner):
        self.inner = inner
        self.calls = 0

    def queue(self):
        self.calls += 1
        return self.inner.queue()

    def __getattr__(self, name):
        return getattr(self.inner, name)


def poll_dedup(sim: SimCluster, ticks: int = 10) -> dict:
    """Monitoring tick: lsjobs + whojobs + a viewjobs refresh per tick.

    Uncached, every tool re-polls the backend (3 polls/tick). A shared
    QueueCache with a TTL longer than the tick collapses each tick to at
    most one real poll.
    """

    def one_tick(backend):
        q = Queue(backend=backend)
        render_table(HEADERS, queue_rows(q), enabled=False)
        render_table(["User", "Running", "Pending", "CPUs", "Mem(GB)", "Share"],
                     utilisation_rows(Queue(backend=backend)), enabled=False)
        ViewModel(lambda: list(Queue(backend=backend))).render()

    raw = _CountingBackend(sim)
    t0 = time.perf_counter()
    for _ in range(ticks):
        one_tick(raw)
    t_raw = time.perf_counter() - t0

    counted = _CountingBackend(sim)
    cached = QueueCache(counted, ttl_s=3600.0)  # snapshot outlives the run
    t0 = time.perf_counter()
    for _ in range(ticks):
        one_tick(cached)
    t_cached = time.perf_counter() - t0

    return {
        "ticks": ticks,
        "polls_uncached": raw.calls,
        "polls_cached": counted.calls,
        "poll_reduction": raw.calls / max(1, counted.calls),
        "uncached_s": t_raw,
        "cached_s": t_cached,
    }


def run() -> dict:
    sim = big_sim()
    q = Queue(backend=sim)
    n = len(q)

    t0 = time.perf_counter()
    table = render_table(HEADERS, queue_rows(q), enabled=False)
    t_ls = time.perf_counter() - t0

    t0 = time.perf_counter()
    vm = ViewModel(lambda: list(Queue(backend=sim)))
    t_vm_init = time.perf_counter() - t0
    t0 = time.perf_counter()
    vm.keys("jjjjjG")      # scroll + jump to bottom
    vm.key("l"); vm.key("s")  # sort by user
    vm.key("f")
    for ch in "task-3":
        vm.key(ch)
    vm.key("ENTER")        # apply filter
    vm.render()
    t_interact = time.perf_counter() - t0

    t0 = time.perf_counter()
    render_table(["User", "Running", "Pending", "CPUs", "Mem(GB)", "Share"],
                 utilisation_rows(q), enabled=False)
    t_who = time.perf_counter() - t0

    dedup = poll_dedup(sim)

    out = {
        "queue_size": n,
        "dedup": dedup,
        "lsjobs_render_ms": t_ls * 1e3,
        "viewjobs_refresh_ms": t_vm_init * 1e3,
        "viewjobs_interaction_ms": t_interact * 1e3,
        "whojobs_ms": t_who * 1e3,
        "filtered_rows": len(vm.state.rows),
    }
    print(f"  {n} jobs in queue")
    print(f"  lsjobs render:      {out['lsjobs_render_ms']:7.1f} ms")
    print(f"  viewjobs refresh:   {out['viewjobs_refresh_ms']:7.1f} ms")
    print(f"  viewjobs interact:  {out['viewjobs_interaction_ms']:7.1f} ms "
          f"(scroll+sort+filter→{out['filtered_rows']} rows)")
    print(f"  whojobs aggregate:  {out['whojobs_ms']:7.1f} ms")
    print(f"  queue-cache dedup:  {dedup['polls_uncached']} polls → "
          f"{dedup['polls_cached']} over {dedup['ticks']} monitoring ticks "
          f"({dedup['poll_reduction']:.0f}× fewer; "
          f"{dedup['uncached_s'] * 1e3:.0f} ms → {dedup['cached_s'] * 1e3:.0f} ms)")
    return out
