"""Benchmark harness — one section per paper claim/table.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only eco,roofline

| section    | paper claim it quantifies                                    |
|------------|--------------------------------------------------------------|
| eco        | §EcoScheduler: tiers, deferral, peak compute avoided, latency |
| events     | event bus vs polling: waitjobs snapshots, dispatch, eco v2    |
| accounting | history store throughput, predictor tier lift, carbon loop    |
| federation | multi-cluster placement throughput, carbon saved by routing   |
| sim        | SimCluster event-calendar day, speedup vs reference scheduler |
| submission | §Statement of Need: boilerplate reduction, submit throughput  |
| queue      | Figure 1 / lsjobs-viewjobs-whojobs on a 2,000-job cluster     |
| gateway    | shared daemon: 8 clients, one poller — poll amplification     |
| obs        | observability: traced vs no-op simulated day, span laws       |
| kernels    | kernels vs oracles + VMEM budgets (TPU-facing)                |
| train      | end-to-end training driver: tokens/s, learn, resume           |
| serve      | batched decode service: prefill/decode throughput             |
| roofline   | the 40-cell dry-run roofline table (deliverable g)            |

Results land in results/benchmarks.json (+ results/roofline.md).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

RESULTS = Path(__file__).resolve().parent.parent / "results"


def bench_serve() -> dict:
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.launch.serve import ServeEngine

    cfg = get_smoke_config("codeqwen1.5-7b")
    engine = ServeEngine(cfg, batch=4, max_seq=64, seed=0)
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
            for _ in range(8)]
    t0 = time.perf_counter()
    engine.serve_requests(reqs, gen_len=16)
    wall = time.perf_counter() - t0
    s = engine.stats
    out = {
        "requests": len(reqs),
        "wall_s": wall,
        "prefill_tok_s": s["prefill_tokens"] / max(s["prefill_s"], 1e-9),
        "decode_tok_s": s["decode_tokens"] / max(s["decode_s"], 1e-9),
    }
    print(f"  {len(reqs)} requests in {wall:.2f}s | "
          f"prefill {out['prefill_tok_s']:.0f} tok/s | "
          f"decode {out['decode_tok_s']:.0f} tok/s")

    # continuous batching on a mixed-length load: steps + occupancy
    from repro.launch.serve import ContinuousBatchingEngine

    mixed = [rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
             for n in (6, 20, 6, 20, 6, 20, 6, 20)]
    cb = ContinuousBatchingEngine(cfg, batch=4, max_seq=64, seed=0)
    t0 = time.perf_counter()
    cb.serve(mixed, gen_len=12)
    out["cb_wall_s"] = time.perf_counter() - t0
    out["cb_decode_steps"] = cb.stats["decode_steps"]
    out["cb_occupancy"] = cb.stats["occupancy_sum"] / cb.stats["decode_steps"]
    static_lb = -(-len(mixed) // 4) * 12
    print(f"  continuous batching: {len(mixed)} mixed requests, "
          f"{out['cb_decode_steps']} decode steps "
          f"(static lower bound {static_lb}), "
          f"occupancy {out['cb_occupancy']:.2f}")
    return out


def bench_roofline() -> dict:
    from benchmarks import roofline

    roofline.main()
    path = RESULTS / "roofline.json"
    return {"cells": len(json.loads(path.read_text())) if path.exists() else 0}


SECTIONS = ["eco", "events", "accounting", "federation", "sim", "submission",
            "queue", "gateway", "obs", "kernels", "train", "serve", "roofline"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("--only", default="", help="comma list of sections")
    ap.add_argument("--publish", action="store_true",
                    help="append tracked sections' headline metrics to the "
                         "committed BENCH_<section>.json trajectory files")
    args = ap.parse_args(argv)
    want = [s for s in args.only.split(",") if s] or SECTIONS

    RESULTS.mkdir(exist_ok=True)
    all_out: dict = {}
    failures = 0
    for name in want:
        print(f"\n=== bench: {name} ===")
        t0 = time.perf_counter()
        try:
            if name == "eco":
                from benchmarks import bench_eco

                all_out[name] = bench_eco.run()
            elif name == "events":
                from benchmarks import bench_events

                all_out[name] = bench_events.run()
            elif name == "accounting":
                from benchmarks import bench_accounting

                all_out[name] = bench_accounting.run()
            elif name == "federation":
                from benchmarks import bench_federation

                all_out[name] = bench_federation.run()
            elif name == "sim":
                from benchmarks import bench_sim

                all_out[name] = bench_sim.run()
            elif name == "submission":
                from benchmarks import bench_submission

                all_out[name] = bench_submission.run()
            elif name == "queue":
                from benchmarks import bench_queue_tools

                all_out[name] = bench_queue_tools.run()
            elif name == "gateway":
                from benchmarks import bench_gateway

                all_out[name] = bench_gateway.run()
            elif name == "obs":
                from benchmarks import bench_obs

                all_out[name] = bench_obs.run()
            elif name == "kernels":
                from benchmarks import bench_kernels

                all_out[name] = bench_kernels.run()
            elif name == "train":
                from benchmarks import bench_train

                all_out[name] = bench_train.run()
            elif name == "serve":
                all_out[name] = bench_serve()
            elif name == "roofline":
                all_out[name] = bench_roofline()
            else:
                print(f"  unknown section {name!r}")
                continue
            print(f"  [{name} done in {time.perf_counter() - t0:.1f}s]")
        except Exception as e:  # noqa: BLE001 — record, keep benching
            failures += 1
            all_out[name] = {"error": f"{type(e).__name__}: {e}"}
            traceback.print_exc()
    (RESULTS / "benchmarks.json").write_text(json.dumps(all_out, indent=1, default=str))
    print(f"\nwrote results/benchmarks.json; failures={failures}")

    if args.publish:
        from benchmarks import trajectory

        for section in trajectory.TRACKED:
            payload = all_out.get(section)
            if not isinstance(payload, dict) or "error" in payload:
                continue
            entry = trajectory.publish(section, payload)
            print(f"published {trajectory.bench_path(section).name}: "
                  f"{json.dumps(entry['rates'])}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
