"""Accounting subsystem benchmark — store throughput, report latency, and
the predictor's effect on eco-mode tier placement.

Five measurements:
  1. HistoryStore append throughput (single-record and batched) — the
     store sits on every job-completion path, so appends must be cheap;
  2. scan + report aggregation latency over a 10k-record archive — the
     interactive ``ecoreport`` budget;
  3. indexed report latency vs archive size (10k/50k/100k records): a
     fixed ``--since`` window must cost the same whatever the archive
     size behind it — flat with the SQLite sidecar index, linear on the
     plain scan;
  4. predictor benefit: a repeat workload with padded 12 h limits but
     ~1 h true runtimes, priced by the plain scheduler vs the
     history-fed one — tier-1 rate and completes-inside-window rate;
  5. a 1k-job SimCluster round trip (submit → run → collect → report)
     proving the closed loop reports nonzero energy/carbon/savings.
"""

from __future__ import annotations

import json
import tempfile
import time
from datetime import datetime, timedelta
from pathlib import Path

import numpy as np

from repro.accounting import (
    EnergyModel,
    HistoryStore,
    JobRecord,
    RuntimePredictor,
    collect,
    report_dict,
)
from repro.core import EcoScheduler, Job, Opts, SimCluster, SubmitEngine

_SCHED = dict(
    weekday_windows=[(0, 360)], weekend_windows=[(0, 420), (660, 960)],
    peak_hours=[(1020, 1200)], horizon_days=14, min_delay_s=0,
)


def _tmp_store(name: str) -> HistoryStore:
    return HistoryStore(Path(tempfile.mkdtemp(prefix="bench-acct-")) / name)


def _record(i: int, rng) -> JobRecord:
    return JobRecord(
        jobid=str(1000000 + i),
        name=f"sweep-{i % 37}",
        user=f"user{i % 11}",
        state="COMPLETED",
        cpus=int(rng.integers(1, 16)),
        time_limit_s=12 * 3600,
        runtime_s=int(rng.uniform(1800, 7200)),
        started_at=f"2026-03-{1 + i % 28:02d}T01:00:00",
        finished_at=f"2026-03-{1 + i % 28:02d}T03:00:00",
        requested_start=f"2026-03-{1 + i % 28:02d}T10:00:00",
        eco_deferred=True,
        eco_tier=1,
        energy_kwh=0.05,
        carbon_gco2=12.0,
        carbon_nodefer_gco2=17.0,
    )


def store_throughput(n: int = 10000) -> dict:
    rng = np.random.default_rng(0)
    records = [_record(i, rng) for i in range(n)]

    one = _tmp_store("one.jsonl")
    t0 = time.perf_counter()
    for r in records[:1000]:
        one.append(r)
    per_record_s = (time.perf_counter() - t0) / 1000

    batched = _tmp_store("batch.jsonl")
    t0 = time.perf_counter()
    batched.append_many(records)
    batch_wall = time.perf_counter() - t0

    t0 = time.perf_counter()
    count = sum(1 for _ in batched.scan())
    scan_wall = time.perf_counter() - t0

    t0 = time.perf_counter()
    rep = report_dict(batched.records(), by="user")
    report_wall = time.perf_counter() - t0

    return {
        "n": n,
        "append_rec_s": 1.0 / per_record_s,
        "append_many_rec_s": n / batch_wall,
        "scan_rec_s": count / scan_wall,
        "report_10k_ms": report_wall * 1e3,
        "report_groups": len(rep["groups"]),
        "report_saved_gco2": rep["total"]["carbon_saved_gco2"],
    }


def indexed_report(sizes=(10_000, 50_000, 100_000), window_records: int = 1440) -> dict:
    """Report latency vs archive size: flat with the index, linear without.

    Archives are date-ordered (one record per simulated minute), so an
    ``ecoreport --since`` window covering the last ``window_records``
    minutes selects the same number of records whatever the archive size —
    the honest way to measure whether query cost follows the *answer* size
    (indexed) or the *archive* size (scan).
    """
    base = datetime(2026, 1, 1, 0, 0, 0)
    out: dict = {"sizes": list(sizes), "window_records": window_records}
    indexed_ms, scan_ms, ingest_s = [], [], []
    for size in sizes:
        store = _tmp_store(f"idx-{size}.jsonl")
        store.append_many([
            JobRecord(
                jobid=str(i), name=f"sweep-{i % 37}", user=f"user{i % 11}",
                state="COMPLETED", cpus=2, time_limit_s=7200,
                runtime_s=1800 + i % 600,
                started_at=(base + timedelta(minutes=i)).isoformat(),
                finished_at=(base + timedelta(minutes=i + 30)).isoformat(),
                energy_kwh=0.05, carbon_gco2=12.0, carbon_nodefer_gco2=17.0,
            )
            for i in range(size)
        ])
        since = base + timedelta(minutes=size - window_records)
        t0 = time.perf_counter()
        store.records(since=since)  # first query pays the one-off ingest
        ingest_s.append(time.perf_counter() - t0)
        reps = 5
        t0 = time.perf_counter()
        for _ in range(reps):
            recs = store.records(since=since)
        indexed_ms.append((time.perf_counter() - t0) / reps * 1e3)
        assert len(recs) == window_records
        report_dict(recs, by="user")
        t0 = time.perf_counter()
        scan_recs = store._records_scan(since=since)
        scan_ms.append((time.perf_counter() - t0) * 1e3)
        assert len(scan_recs) == window_records
    out["index_ingest_s"] = ingest_s
    out["window_query_indexed_ms"] = indexed_ms
    out["window_query_scan_ms"] = scan_ms
    # flatness: indexed latency at the biggest archive vs the smallest —
    # ~1.0 means cost follows the window, not the archive
    out["indexed_flatness_ratio"] = indexed_ms[-1] / max(indexed_ms[0], 1e-9)
    out["scan_growth_ratio"] = scan_ms[-1] / max(scan_ms[0], 1e-9)
    print("  indexed report (fixed 1-day window): "
          + ", ".join(f"{s//1000}k→{m:.1f}ms" for s, m in zip(sizes, indexed_ms))
          + f" (flatness ×{out['indexed_flatness_ratio']:.2f}; "
          + f"scan grows ×{out['scan_growth_ratio']:.1f})")
    return out


def predictor_benefit(n_jobs: int = 300, seed: int = 3) -> dict:
    """Repeat workload, padded limits: plain vs history-fed scheduling."""
    rng = np.random.default_rng(seed)
    store = _tmp_store("hist.jsonl")
    store.append_many(
        [
            JobRecord(jobid=str(i), name="blast", user="bench",
                      state="COMPLETED", cpus=4, time_limit_s=12 * 3600,
                      runtime_s=int(rng.uniform(2700, 4500)))
            for i in range(60)
        ]
    )
    base = EcoScheduler(**_SCHED)
    pred = EcoScheduler(**_SCHED, predictor=RuntimePredictor(store))

    start = datetime(2026, 1, 5)
    submissions = [  # identical workload for both arms
        (
            start + timedelta(days=int(rng.integers(0, 120)),
                              hours=int(rng.integers(8, 18)),
                              minutes=int(rng.integers(0, 60))),
            int(rng.uniform(2700, 4500)),
        )
        for _ in range(n_jobs)
    ]
    out = {}
    for label, sched in (("baseline", base), ("predictor", pred)):
        tier1 = in_window = 0
        t0 = time.perf_counter()
        for t, actual_s in submissions:
            d = sched.decide(12 * 3600, t, name="blast", user="bench")
            if d.tier == 1:
                tier1 += 1
            if (d.window_end is not None
                    and d.begin + timedelta(seconds=actual_s) <= d.window_end):
                in_window += 1
        out[label] = {
            "tier1_rate": tier1 / n_jobs,
            "completes_in_window_rate": in_window / n_jobs,
            "decide_ms": (time.perf_counter() - t0) / n_jobs * 1e3,
        }
    return out


def sim_round_trip(n_jobs: int = 1000) -> dict:
    """submit → run → collect → report over a simulated 1k-job history."""
    rng = np.random.default_rng(11)
    sim = SimCluster(
        nodes=None, now=datetime(2026, 3, 16, 9, 0), default_user="bench",
    )
    for node in sim.nodes:
        node.cpus = 512  # headroom: this measures accounting, not contention
    engine = SubmitEngine(
        sim, eco=True, coalesce=False,
        scheduler=EcoScheduler(**_SCHED), now=sim.now,
    )
    jobs = [
        Job(name=f"etl-{i % 23}", command="true",
            opts=Opts.new(threads=2, memory="2GB",
                          time=float(int(rng.integers(1, 13)))),
            sim_duration_s=int(rng.uniform(900, 5400)))
        for i in range(n_jobs)
    ]
    t0 = time.perf_counter()
    engine.submit_many(jobs)
    sim.run_until_idle()
    sim_wall = time.perf_counter() - t0

    store = _tmp_store("sim.jsonl")
    t0 = time.perf_counter()
    n_collected = collect(sim, store, EnergyModel())
    collect_wall = time.perf_counter() - t0
    rep = report_dict(store.records(), by="tool")
    tot = rep["total"]
    return {
        "jobs": n_jobs,
        "collected": n_collected,
        "sim_wall_s": sim_wall,
        "collect_wall_s": collect_wall,
        "energy_kwh": tot["energy_kwh"],
        "carbon_gco2": tot["carbon_gco2"],
        "carbon_saved_gco2": tot["carbon_saved_gco2"],
        "eco_deferred": tot["eco_deferred"],
        "loop_closes": (
            tot["energy_kwh"] > 0
            and tot["carbon_gco2"] > 0
            and tot["carbon_saved_gco2"] > 0
        ),
    }


def run() -> dict:
    out = {
        "store": store_throughput(),
        "indexed": indexed_report(),
        "predictor": predictor_benefit(),
        "round_trip": sim_round_trip(),
    }
    s = out["store"]
    print(f"  store: append {s['append_rec_s']:.0f} rec/s "
          f"(batched {s['append_many_rec_s']:.0f}), "
          f"scan {s['scan_rec_s']:.0f} rec/s, "
          f"report over 10k in {s['report_10k_ms']:.1f} ms")
    p = out["predictor"]
    print(f"  predictor: tier-1 {p['baseline']['tier1_rate']:.0%} → "
          f"{p['predictor']['tier1_rate']:.0%}, "
          f"completes-in-window {p['baseline']['completes_in_window_rate']:.0%} → "
          f"{p['predictor']['completes_in_window_rate']:.0%}")
    r = out["round_trip"]
    print(f"  round trip: {r['jobs']} sim jobs → {r['collected']} records, "
          f"{r['energy_kwh']:.2f} kWh, {r['carbon_gco2']:.0f} g CO2, "
          f"saved {r['carbon_saved_gco2']:.0f} g "
          f"({r['eco_deferred']} deferred) | loop_closes={r['loop_closes']}")
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
