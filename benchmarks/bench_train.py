"""End-to-end training benchmark: tokens/s on the local device + the
fault-tolerance overheads that matter at fleet scale (checkpoint save cost,
resume cost, data-pipeline straggler recovery)."""

from __future__ import annotations

import tempfile
import time

import numpy as np

import repro.configs.nbi100m as nbi100m_mod
from repro.launch.train import build_argparser, train


def _mini_config(orig):
    return orig().replace(
        name="bench-mini", n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
        head_dim=32, d_ff=512, vocab_size=2048,
    )


def run() -> dict:
    orig = nbi100m_mod.config
    nbi100m_mod.config = lambda: _mini_config(orig)
    try:
        ckpt = tempfile.mkdtemp(prefix="bench-train-")
        args = build_argparser().parse_args([
            "--arch", "nbi-100m", "--steps", "30", "--global-batch", "8",
            "--seq", "128", "--ckpt-dir", ckpt, "--ckpt-every", "10",
            "--log-every", "10",
        ])
        t0 = time.perf_counter()
        result = train(args)
        wall = time.perf_counter() - t0
        losses = [m["loss"] for m in result["metrics"]]
        toks = 30 * 8 * 128

        # resume cost: restart the same run for 5 more steps
        t0 = time.perf_counter()
        args2 = build_argparser().parse_args([
            "--arch", "nbi-100m", "--steps", "35", "--global-batch", "8",
            "--seq", "128", "--ckpt-dir", ckpt, "--ckpt-every", "100",
            "--log-every", "5",
        ])
        train(args2)
        resume_wall = time.perf_counter() - t0

        out = {
            "steps": 30,
            "tokens_per_s": toks / wall,
            "loss_first": losses[0],
            "loss_last": losses[-1],
            "learned": losses[-1] < losses[0],
            "resume_5_steps_s": resume_wall,
        }
        print(f"  30 steps of bench-mini: {out['tokens_per_s']:.0f} tok/s, "
              f"loss {out['loss_first']:.3f} → {out['loss_last']:.3f}")
        print(f"  restart+5 steps (restore incl. jit): {resume_wall:.1f}s")
        return out
    finally:
        nbi100m_mod.config = orig
