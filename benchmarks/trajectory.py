"""Benchmark trajectory files — ``BENCH_<section>.json`` at the repo root.

Each tracked section appends one entry per publish: a timestamp, the git
commit, and a flat dict of headline metrics pulled out of that section's
``results/benchmarks.json`` payload. The files are committed, so the
repo's own history carries the performance trajectory — and CI can fail
a change that regresses a rate by more than the tolerance without any
external dashboard.

Two kinds of tracked values:

* **gated metrics** — rates (higher is better). A publish that drops one
  by more than ``TOLERANCE`` vs the last committed entry is a regression.
  Latency-ish numbers are recorded in the entries for plotting but NOT
  gated: wall-clock on shared CI runners is too noisy for a hard gate.
* **invariants** — booleans that must simply be true (conservation,
  scalar-equivalence). Any publish with a false invariant fails.
"""

from __future__ import annotations

import json
import subprocess
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
RESULTS = ROOT / "results"

#: regression tolerance on gated rate metrics (fraction of baseline)
TOLERANCE = 0.30

#: section → {"rates": {metric name: path into the section payload},
#:            "invariants": {...}, "extra": {... recorded, never gated}}
TRACKED = {
    "federation": {
        "rates": {
            "vectorized_placements_per_s":
                ("vectorized", "vectorized_placements_per_s"),
            "day_jobs_per_s": ("day", "day_jobs_per_s"),
            "engine_placement_jobs_per_s": ("placement_jobs_per_s",),
        },
        "invariants": {
            "scalar_equivalent": ("vectorized", "scalar_equivalent"),
            "day_conserved": ("day", "conserved"),
            "conserved": ("conserved",),
        },
        "extra": {
            "day_jobs": ("day", "jobs"),
            "max_reconcile_drift_cpu_s": ("day", "max_reconcile_drift_cpu_s"),
            "carbon_saved_pct": ("carbon_saved_pct",),
        },
    },
    "obs": {
        "rates": {
            "noop_day_jobs_per_s": ("noop_day_jobs_per_s",),
        },
        "invariants": {
            # fully traced day may cost at most OVERHEAD_LIMIT_PCT (5%)
            # vs the no-op day — best-of-N on both sides
            "overhead_ok": ("overhead_ok",),
            # spans finalized == jobs archived == jobs submitted
            "span_conservation": ("span_conservation",),
        },
        "extra": {
            "overhead_pct": ("overhead_pct",),
            "instrumented_day_jobs_per_s": ("instrumented_day_jobs_per_s",),
            "metric_families": ("metric_families",),
        },
    },
    "sim": {
        "rates": {
            "day_jobs_per_s": ("day", "day_jobs_per_s"),
            "wakeups_per_s": ("wake", "wakeups_per_s"),
        },
        "invariants": {
            "conserved": ("day", "conserved"),
            # event-calendar scheduler ≥5× the pre-PR full-sweep reference
            # on the deep-backlog worst case
            "speedup_ok": ("reference", "speedup_ok"),
        },
        "extra": {
            "day_jobs": ("day", "jobs"),
            "considered_per_job": ("day", "considered_per_job"),
            "speedup_vs_reference": ("reference", "speedup_vs_reference"),
            "reference_jobs": ("reference", "jobs"),
            "stress_1m_jobs_per_s": ("stress_1m", "day_jobs_per_s"),
            "stress_1m_conserved": ("stress_1m", "conserved"),
        },
    },
    "gateway": {
        "rates": {
            "daemon_queue_rps": ("daemon_queue_rps",),
            "storm_queue_rps": ("storm", "storm_queue_rps"),
        },
        "invariants": {
            # >= N_CLIENTS x fewer backend polls than independent processes
            "poll_amplification_ok": ("poll_amplification_ok",),
            # same job ids / names / final states in both deployments
            "outcomes_identical": ("outcomes_identical",),
            # read storm (protocol v2): >=10x queue-RPC throughput over the
            # pinned PR-9 thread-per-connection baseline...
            "storm_throughput_ratio_ok": ("storm", "throughput_ratio_ok"),
            # ...>=20x fewer wire bytes/poll for a per-user filtered watcher...
            "storm_filtered_bytes_ratio_ok": ("storm", "filtered_bytes_ratio_ok"),
            # ...v2 tail latency below the legacy median (relative, so CI
            # runner speed cancels out)...
            "storm_latency_ok": ("storm", "latency_ok"),
            # ...and both protocols serve identical rows off one snapshot
            "storm_rows_identical": ("storm", "rows_identical"),
        },
        "extra": {
            "poll_amplification_x": ("poll_amplification_x",),
            "direct_polls": ("direct_polls",),
            "daemon_polls": ("daemon_polls",),
            "clients": ("clients",),
            "jobs": ("jobs",),
            "storm_jobs": ("storm", "jobs"),
            "storm_throughput_ratio_x": ("storm", "throughput_ratio_x"),
            "storm_filtered_bytes_ratio_x": ("storm", "filtered_bytes_ratio_x"),
            "storm_legacy_queue_rps": ("storm", "legacy_queue_rps"),
            "storm_p50_ms": ("storm", "storm_p50_ms"),
            "storm_p99_ms": ("storm", "storm_p99_ms"),
            "storm_legacy_p50_ms": ("storm", "legacy_p50_ms"),
            "storm_legacy_p99_ms": ("storm", "legacy_p99_ms"),
            "storm_legacy_bytes_per_poll": ("storm", "legacy_bytes_per_poll"),
            "storm_filtered_bytes_per_poll":
                ("storm", "filtered_bytes_per_poll"),
            "storm_snapshot_encodes": ("storm", "snapshot_encodes"),
            "storm_delta_hits": ("storm", "delta_hits"),
            "storm_unchanged_hits": ("storm", "unchanged_hits"),
        },
    },
    "accounting": {
        "rates": {
            "append_many_rec_s": ("store", "append_many_rec_s"),
            "scan_rec_s": ("store", "scan_rec_s"),
        },
        "invariants": {},
        "extra": {
            "window_query_indexed_ms_max_archive":
                ("indexed", "window_query_indexed_ms", -1),
            "indexed_flatness_ratio": ("indexed", "indexed_flatness_ratio"),
            "report_10k_ms": ("store", "report_10k_ms"),
        },
    },
}


def bench_path(section: str) -> Path:
    return ROOT / f"BENCH_{section}.json"


def _dig(payload: dict, path: tuple):
    cur = payload
    for step in path:
        if isinstance(step, int):
            cur = cur[step]
        else:
            if not isinstance(cur, dict) or step not in cur:
                return None
            cur = cur[step]
    return cur


def _git_commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=ROOT,
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
    except Exception:
        return ""


def extract(section: str, payload: dict) -> dict:
    """The trajectory entry for one section's benchmark payload."""
    spec = TRACKED[section]
    entry = {
        "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "commit": _git_commit(),
        "rates": {k: _dig(payload, p) for k, p in spec["rates"].items()},
        "invariants": {k: _dig(payload, p) for k, p in spec["invariants"].items()},
        "extra": {k: _dig(payload, p) for k, p in spec["extra"].items()},
    }
    return entry


def load_trajectory(section: str) -> list:
    path = bench_path(section)
    if not path.is_file():
        return []
    try:
        data = json.loads(path.read_text())
    except json.JSONDecodeError:
        return []
    return data if isinstance(data, list) else []


def publish(section: str, payload: dict) -> dict:
    """Append this run's entry to ``BENCH_<section>.json``; returns it."""
    entry = extract(section, payload)
    traj = load_trajectory(section)
    traj.append(entry)
    bench_path(section).write_text(json.dumps(traj, indent=1) + "\n")
    return entry


def check(section: str, payload: dict, *, tolerance: float = TOLERANCE) -> list:
    """Regression check vs the last committed trajectory entry.

    Returns a list of human-readable failures (empty == pass). A missing
    trajectory or baseline metric is never a failure — the first publish
    IS the baseline.
    """
    failures: list = []
    entry = extract(section, payload)
    for name, ok in entry["invariants"].items():
        if ok is False:
            failures.append(f"{section}: invariant {name} is false")
    traj = load_trajectory(section)
    if not traj:
        return failures
    baseline = traj[-1].get("rates", {})
    for name, value in entry["rates"].items():
        base = baseline.get(name)
        if base is None or value is None or base <= 0:
            continue
        if value < base * (1.0 - tolerance):
            failures.append(
                f"{section}: {name} regressed {base:.0f} → {value:.0f} "
                f"(-{100 * (1 - value / base):.0f}%, tolerance "
                f"{100 * tolerance:.0f}%)"
            )
    return failures
