"""CI gate: fail on benchmark regressions vs the committed trajectories.

    PYTHONPATH=src python -m benchmarks.check_bench

Reads ``results/benchmarks.json`` (produced by ``benchmarks.run``) and
compares every tracked section against the last entry of its committed
``BENCH_<section>.json`` (see :mod:`benchmarks.trajectory`): a gated rate
more than the tolerance below baseline, or a false invariant
(conservation, scalar-equivalence), exits nonzero. Sections absent from
the results (e.g. a ``--only`` subset) are skipped; a missing trajectory
file just means this run becomes the baseline.
"""

from __future__ import annotations

import argparse
import json
import sys

from benchmarks.trajectory import RESULTS, TOLERANCE, TRACKED, check


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.check_bench")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE,
                    help=f"allowed fractional rate drop (default {TOLERANCE})")
    ap.add_argument("--results", default=str(RESULTS / "benchmarks.json"),
                    help="benchmarks.json to check")
    args = ap.parse_args(argv)

    try:
        results = json.loads(open(args.results).read())
    except (OSError, json.JSONDecodeError) as e:
        print(f"cannot read {args.results}: {e}", file=sys.stderr)
        return 2

    failures: list = []
    checked = 0
    for section in TRACKED:
        payload = results.get(section)
        if not isinstance(payload, dict) or "error" in payload:
            continue
        checked += 1
        failures.extend(check(section, payload, tolerance=args.tolerance))
    if failures:
        print("benchmark regressions:", file=sys.stderr)
        for f in failures:
            print(f"  FAIL {f}", file=sys.stderr)
        return 1
    print(f"benchmarks OK ({checked} tracked section(s), "
          f"tolerance {100 * args.tolerance:.0f}%)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
