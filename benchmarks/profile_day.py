"""Profile the simulated day — so the next bottleneck is one command away.

    PYTHONPATH=src python -m benchmarks.profile_day --jobs 20000 --profile
    PYTHONPATH=src python -m benchmarks.profile_day --shape deep --reference

Shapes:
  * ``day``  — the bench_sim hourly-cohort day on a 2,048-cpu cluster
               (capacity roughly keeps up; exercises the event calendar);
  * ``deep`` — the deep-backlog worst case (one undersized node, queue
               depth ≈ job count; exercises the eligibility sets and the
               max-free-capacity early exit).

``--reference`` runs the same workload through
``repro.core.simref.ReferenceSimCluster`` instead — profile both and diff
the hot functions to see exactly what the event calendar bought.
``--profile`` wraps the run in cProfile and prints the top of the
cumulative-time table (tune with ``--top``).
"""

from __future__ import annotations

import argparse
import cProfile
import pstats
import time

from benchmarks.bench_sim import _deep_backlog, simulated_day


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.profile_day")
    ap.add_argument("--jobs", type=int, default=20000,
                    help="workload size (default 20000)")
    ap.add_argument("--shape", choices=["day", "deep"], default="day")
    ap.add_argument("--reference", action="store_true",
                    help="run the pre-calendar reference scheduler instead")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the run in cProfile and print hot functions")
    ap.add_argument("--top", type=int, default=25,
                    help="rows of the profile table to print (default 25)")
    args = ap.parse_args(argv)

    if args.reference:
        from repro.core.simref import ReferenceSimCluster as cluster_cls
    else:
        from repro.core import SimCluster as cluster_cls

    def work():
        if args.shape == "deep":
            wall = _deep_backlog(cluster_cls, args.jobs)
        else:
            if args.reference:
                raise SystemExit(
                    "--shape day --reference would take hours at this size; "
                    "use --shape deep (the contested case) or a tiny --jobs"
                )
            wall = simulated_day(args.jobs)["wall_s"]
        return wall

    label = "reference" if args.reference else "event-calendar"
    print(f"profiling shape={args.shape} jobs={args.jobs} ({label})")
    if args.profile:
        pr = cProfile.Profile()
        pr.enable()
        wall = work()
        pr.disable()
        stats = pstats.Stats(pr)
        stats.sort_stats("cumulative").print_stats(args.top)
    else:
        t0 = time.perf_counter()
        work()
        wall = time.perf_counter() - t0
    print(f"done: {args.jobs} jobs in {wall:.2f}s "
          f"({args.jobs / wall:.0f} jobs/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
