"""Federation benchmark — placement throughput and carbon saved by routing.

Five measurements:
  1. placement + submission throughput: 1,000 jobs routed by the Placer
     across 4 heterogeneous sim clusters through the SubmitEngine (one
     live queue snapshot per member per batch, not per job);
  2. vectorized placement throughput: 100k specs through
     ``Placer.place_many`` (the numpy hot path), cross-checked for exact
     equality against the scalar ``place_spec`` loop on a sample —
     the headline ≥50k placements/s target lives here;
  3. a full simulated day: ``NBI_BENCH_DAY_JOBS`` jobs (default 100,000)
     in hourly cohorts through SubmitEngine + FederatedBackend with an
     EventCollector archiving terminal events, asserting conservation
     and zero tracker reconciliation drift along the way;
  4. carbon saved vs a single-cluster baseline: the same eco workload run
     (a) entirely on the default (dirty-grid) cluster and (b) through the
     carbon-aware router across dirty/green members — collected into the
     accounting archive and differenced;
  5. conservation: every submitted job appears exactly once across the
     federated queue, the accounting fan-out and the report — no job
     lost, none double-counted.
"""

from __future__ import annotations

import os
import tempfile
import time
from datetime import datetime
from pathlib import Path

from repro.accounting import (
    EnergyModel,
    EventCollector,
    HistoryStore,
    collect,
    report_dict,
)
from repro.core import (
    ClusterHandle,
    ClusterRegistry,
    EcoScheduler,
    FederatedBackend,
    Job,
    Opts,
    Placer,
    SimCluster,
    SimNode,
    SubmitEngine,
)
from repro.core.eco import CarbonTrace

T0 = datetime(2026, 3, 18, 10, 0, 0)  # Wednesday morning

_WINDOWS = dict(
    weekday_windows=[(0, 360)], weekend_windows=[(0, 420), (660, 960)],
    peak_hours=[(1020, 1200)], horizon_days=14, min_delay_s=0,
)

#: four members on divergent grids (flat gCO2/kWh): one dirty default,
#: one mid, two green — capacities differ so feasibility matters too
MEMBER_SPECS = [
    ("coal", 600.0, 8, 64),
    ("gas", 350.0, 4, 32),
    ("wind", 80.0, 6, 64),
    ("hydro", 40.0, 4, 48),
]


def _handle(name: str, gco2: float, nodes: int, cpus: int,
            now: datetime = T0) -> ClusterHandle:
    trace = CarbonTrace([gco2] * 168)
    return ClusterHandle(
        name=name, kind="sim",
        backend=SimCluster(
            nodes=[SimNode(f"{name}-n{i:02d}", cpus=cpus, memory_mb=262144)
                   for i in range(nodes)],
            now=now, default_user="bench", name=name,
        ),
        carbon_trace=trace,
        scheduler=EcoScheduler(carbon_trace=trace, **_WINDOWS),
        nodes=nodes, cpus_per_node=cpus,
    )


def make_federation() -> FederatedBackend:
    return FederatedBackend(
        ClusterRegistry([_handle(*spec) for spec in MEMBER_SPECS])
    )


def _jobs(n: int) -> "list[Job]":
    return [
        Job(
            name=f"sweep-{i}",
            command=f"echo {i}",
            opts=Opts(threads=1 + (i % 4), memory_mb=2048,
                      time_s=1800 * (1 + i % 3)),
            sim_duration_s=600,
        )
        for i in range(n)
    ]


def _collect_report(backend, tag: str) -> dict:
    """Run the cluster dry, archive it, and aggregate per-cluster."""
    backend.run_until_idle(max_days=30)
    with tempfile.TemporaryDirectory() as d:
        store = HistoryStore(Path(d) / f"{tag}.jsonl")
        model = EnergyModel(
            cluster_traces={n: CarbonTrace([g] * 168)
                            for n, g, _, _ in MEMBER_SPECS},
            default_cluster=MEMBER_SPECS[0][0],
        )
        collected = collect(backend, store, model)
        rep = report_dict(store.records(), by="cluster")
    return {"collected": collected, "report": rep}


def _specs(n: int) -> list:
    return [
        {
            "cpus": 1 + (i % 8),
            "memory_mb": 2048 if i % 5 else 131072,
            "time_s": 1800 * (1 + i % 4),
            "name": f"sweep-{i % 53}",
            "tool": "" if i % 3 else "kraken2",
            "eco": bool(i % 2),
        }
        for i in range(n)
    ]


def vectorized_placements(n: int = 100_000) -> dict:
    """``place_many`` throughput on a big mixed batch + exactness check."""
    fed = make_federation()
    placer = fed.placer
    specs = _specs(n)
    t0 = time.perf_counter()
    placements = placer.place_many(specs, T0)
    wall = time.perf_counter() - t0
    rate = n / wall
    placer.clear_inflight()

    # exactness: the same prefix through the scalar reference on a fresh
    # placer must be bit-identical (the full property pin lives in
    # tests/test_placer_vectorized.py; this is the benchmark's own guard)
    sample = specs[:2000]
    ref_placer = Placer(fed.registry)
    ref = [
        ref_placer.place_spec(
            cpus=s["cpus"], memory_mb=s["memory_mb"], time_s=s["time_s"],
            now=T0, name=s["name"], tool=s["tool"], eco=s["eco"],
        )
        for s in sample
    ]
    vec_placer = Placer(fed.registry)
    vec = vec_placer.place_many(sample, T0)
    exact = all(
        v.cluster == r.cluster
        and v.wait_s == r.wait_s
        and v.carbon_gco2_kwh == r.carbon_gco2_kwh
        and v.candidates == r.candidates
        for v, r in zip(vec, ref)
    ) and vec_placer._inflight == ref_placer._inflight
    fed.close()
    out = {
        "specs": n,
        "wall_s": wall,
        "vectorized_placements_per_s": rate,
        "scalar_equivalent": exact,
        "meets_50k_target": rate >= 50_000,
    }
    print(f"  vectorized: {n} placements in {wall:.2f}s "
          f"({rate:.0f}/s, target ≥50k) | scalar-equivalent={exact}")
    return out


def simulated_day(total_jobs: "int | None" = None, *, on_backend=None) -> dict:
    """A full day of hourly cohorts through the whole federated stack.

    ``on_backend(fed)`` (optional) is called once the federation exists —
    the obs benchmark uses it to attach a
    :class:`~repro.obs.trace.JobTracer` to the bus. Whatever callable it
    returns is invoked as teardown after the day drains, before close.
    """
    total_jobs = total_jobs or int(os.environ.get("NBI_BENCH_DAY_JOBS", "100000"))
    day_t0 = datetime(2026, 3, 18, 0, 0, 0)
    handles = [_handle(*spec, now=day_t0) for spec in MEMBER_SPECS]
    fed = FederatedBackend(ClusterRegistry(handles))
    teardown = on_backend(fed) if on_backend is not None else None
    engine = SubmitEngine(fed, eco=True, coalesce=False, now=day_t0)
    with tempfile.TemporaryDirectory() as d:
        store = HistoryStore(Path(d) / "day.jsonl")
        model = EnergyModel(
            cluster_traces={n: CarbonTrace([g] * 168)
                            for n, g, _, _ in MEMBER_SPECS},
            default_cluster=MEMBER_SPECS[0][0],
        )
        coll = EventCollector(fed, store, model, flush_every=1024).attach(fed.bus)
        per_hour = total_jobs // 24
        submitted = 0
        max_drift = 0.0
        t0 = time.perf_counter()
        for hour in range(24):
            n = per_hour + (total_jobs % 24 if hour == 23 else 0)
            jobs = [
                Job(name=f"day-{hour:02d}-{i}", command="true",
                    opts=Opts(threads=1 + (i % 4), memory_mb=2048,
                              time_s=1800 * (1 + i % 3)),
                    sim_duration_s=300 + (i % 7) * 120)
                for i in range(n)
            ]
            submitted += len(engine.submit_many(jobs).ids)
            fed.advance(3600)
            drift = fed.tracker.reconcile()
            if drift:
                max_drift = max(max_drift, max(abs(v) for v in drift.values()))
        fed.run_until_idle(max_days=30)
        coll.detach()
        wall = time.perf_counter() - t0
        archived = len(store.ids())
        rep = report_dict(store.records(), by="cluster")
    conserved = submitted == total_jobs == archived == rep["total"]["jobs"]
    if callable(teardown):
        teardown()
    fed.close()
    out = {
        "jobs": total_jobs,
        "wall_s": wall,
        "day_jobs_per_s": total_jobs / wall,
        "archived": archived,
        "report_jobs": rep["total"]["jobs"],
        "conserved": conserved,
        "max_reconcile_drift_cpu_s": max_drift,
        "carbon_saved_gco2": rep["total"]["carbon_saved_gco2"],
    }
    print(f"  day: {total_jobs} jobs simulated+archived in {wall:.1f}s "
          f"({out['day_jobs_per_s']:.0f} jobs/s) | conserved={conserved} "
          f"| max reconcile drift {max_drift:g} cpu·s")
    return out


def run() -> dict:
    out: dict = {}

    # -- 0. the vectorized hot path + the full simulated day ------------------
    out["vectorized"] = vectorized_placements()
    out["day"] = simulated_day()

    # -- 1. placement throughput: 1k jobs across 4 clusters -------------------
    fed = make_federation()
    engine = SubmitEngine(fed, eco=True, coalesce=False, now=T0)
    jobs = _jobs(1000)
    t0 = time.perf_counter()
    result = engine.submit_many(jobs)
    wall = time.perf_counter() - t0
    out["jobs"] = len(result.ids)
    out["placement_jobs_per_s"] = len(result.ids) / wall
    out["clusters_used"] = sorted(result.placements)
    by_cluster: dict[str, int] = {}
    for jid in result.ids:
        by_cluster[jid.split(":")[0]] = by_cluster.get(jid.split(":")[0], 0) + 1
    out["placed"] = by_cluster
    green = sum(by_cluster.get(n, 0) for n in ("wind", "hydro"))
    out["green_fraction"] = green / len(result.ids)
    print(f"  placement: {len(result.ids)} jobs across "
          f"{len(MEMBER_SPECS)} clusters in {wall:.2f}s "
          f"({out['placement_jobs_per_s']:.0f} jobs/s)")
    print(f"  placed: {by_cluster} → {out['green_fraction']:.0%} on the "
          f"two lowest-carbon members")

    # -- 1b. urgent batch spreads by capacity (in-flight charging) ------------
    urgent_fed = make_federation()
    urgent_engine = SubmitEngine(urgent_fed, eco=False, coalesce=False)
    urgent = urgent_engine.submit_many(_jobs(200))
    spread: dict[str, int] = {}
    for jid in urgent.ids:
        spread[jid.split(":")[0]] = spread.get(jid.split(":")[0], 0) + 1
    out["urgent_spread"] = spread
    print(f"  urgent batch of 200 spreads across members: {spread}")

    # -- 2. conservation: nothing lost, nothing double-counted ----------------
    queue_ids = [r["jobid"] for r in fed.queue()]
    out["queued"] = len(queue_ids)
    out["queue_unique"] = len(set(queue_ids))
    fed_result = _collect_report(fed, "fed")
    rep = fed_result["report"]
    out["archived"] = fed_result["collected"]
    out["report_jobs"] = rep["total"]["jobs"]
    conserved = (
        out["queue_unique"] == len(result.ids)
        and out["archived"] == len(result.ids)
        and out["report_jobs"] == len(result.ids)
    )
    out["conserved"] = conserved
    print(f"  conservation: queue {out['queue_unique']}/{len(result.ids)} "
          f"unique, archive {out['archived']}, report {out['report_jobs']} "
          f"→ {'OK' if conserved else 'MISMATCH'}")

    # -- 3. carbon saved vs single-cluster baseline ---------------------------
    # same workload, everything forced onto the dirty default member
    baseline = make_federation()
    base_jobs = _jobs(1000)
    for j in base_jobs:
        j.cluster = MEMBER_SPECS[0][0]
    SubmitEngine(baseline, eco=True, coalesce=False, now=T0).submit_many(base_jobs)
    base_rep = _collect_report(baseline, "baseline")["report"]
    fed_carbon = rep["total"]["carbon_gco2"]
    base_carbon = base_rep["total"]["carbon_gco2"]
    out["carbon_gco2_federated"] = fed_carbon
    out["carbon_gco2_single_cluster"] = base_carbon
    out["carbon_saved_gco2"] = base_carbon - fed_carbon
    out["carbon_saved_pct"] = (
        100.0 * (base_carbon - fed_carbon) / base_carbon if base_carbon else 0.0
    )
    out["placement_saved_gco2_reported"] = rep["total"]["placement_saved_gco2"]
    print(f"  carbon: federated {fed_carbon:.0f} g vs single-cluster "
          f"{base_carbon:.0f} g → saved {out['carbon_saved_gco2']:.0f} g "
          f"({out['carbon_saved_pct']:.0f}%)")
    print(f"  report's own placement counterfactual: "
          f"{out['placement_saved_gco2_reported']:+.0f} g")
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
