"""Federation benchmark — placement throughput and carbon saved by routing.

Three measurements:
  1. placement + submission throughput: 1,000 jobs routed by the Placer
     across 4 heterogeneous sim clusters through the SubmitEngine (one
     live queue snapshot per member per batch, not per job);
  2. carbon saved vs a single-cluster baseline: the same eco workload run
     (a) entirely on the default (dirty-grid) cluster and (b) through the
     carbon-aware router across dirty/green members — collected into the
     accounting archive and differenced;
  3. conservation: every submitted job appears exactly once across the
     federated queue, the accounting fan-out and the report — no job
     lost, none double-counted.
"""

from __future__ import annotations

import tempfile
import time
from datetime import datetime
from pathlib import Path

from repro.accounting import EnergyModel, HistoryStore, collect, report_dict
from repro.core import (
    ClusterHandle,
    ClusterRegistry,
    EcoScheduler,
    FederatedBackend,
    Job,
    Opts,
    SimCluster,
    SimNode,
    SubmitEngine,
)
from repro.core.eco import CarbonTrace

T0 = datetime(2026, 3, 18, 10, 0, 0)  # Wednesday morning

_WINDOWS = dict(
    weekday_windows=[(0, 360)], weekend_windows=[(0, 420), (660, 960)],
    peak_hours=[(1020, 1200)], horizon_days=14, min_delay_s=0,
)

#: four members on divergent grids (flat gCO2/kWh): one dirty default,
#: one mid, two green — capacities differ so feasibility matters too
MEMBER_SPECS = [
    ("coal", 600.0, 8, 64),
    ("gas", 350.0, 4, 32),
    ("wind", 80.0, 6, 64),
    ("hydro", 40.0, 4, 48),
]


def _handle(name: str, gco2: float, nodes: int, cpus: int) -> ClusterHandle:
    trace = CarbonTrace([gco2] * 168)
    return ClusterHandle(
        name=name, kind="sim",
        backend=SimCluster(
            nodes=[SimNode(f"{name}-n{i:02d}", cpus=cpus, memory_mb=262144)
                   for i in range(nodes)],
            now=T0, default_user="bench", name=name,
        ),
        carbon_trace=trace,
        scheduler=EcoScheduler(carbon_trace=trace, **_WINDOWS),
        nodes=nodes, cpus_per_node=cpus,
    )


def make_federation() -> FederatedBackend:
    return FederatedBackend(
        ClusterRegistry([_handle(*spec) for spec in MEMBER_SPECS])
    )


def _jobs(n: int) -> "list[Job]":
    return [
        Job(
            name=f"sweep-{i}",
            command=f"echo {i}",
            opts=Opts(threads=1 + (i % 4), memory_mb=2048,
                      time_s=1800 * (1 + i % 3)),
            sim_duration_s=600,
        )
        for i in range(n)
    ]


def _collect_report(backend, tag: str) -> dict:
    """Run the cluster dry, archive it, and aggregate per-cluster."""
    backend.run_until_idle(max_days=30)
    with tempfile.TemporaryDirectory() as d:
        store = HistoryStore(Path(d) / f"{tag}.jsonl")
        model = EnergyModel(
            cluster_traces={n: CarbonTrace([g] * 168)
                            for n, g, _, _ in MEMBER_SPECS},
            default_cluster=MEMBER_SPECS[0][0],
        )
        collected = collect(backend, store, model)
        rep = report_dict(store.records(), by="cluster")
    return {"collected": collected, "report": rep}


def run() -> dict:
    out: dict = {}

    # -- 1. placement throughput: 1k jobs across 4 clusters -------------------
    fed = make_federation()
    engine = SubmitEngine(fed, eco=True, coalesce=False, now=T0)
    jobs = _jobs(1000)
    t0 = time.perf_counter()
    result = engine.submit_many(jobs)
    wall = time.perf_counter() - t0
    out["jobs"] = len(result.ids)
    out["placement_jobs_per_s"] = len(result.ids) / wall
    out["clusters_used"] = sorted(result.placements)
    by_cluster: dict[str, int] = {}
    for jid in result.ids:
        by_cluster[jid.split(":")[0]] = by_cluster.get(jid.split(":")[0], 0) + 1
    out["placed"] = by_cluster
    green = sum(by_cluster.get(n, 0) for n in ("wind", "hydro"))
    out["green_fraction"] = green / len(result.ids)
    print(f"  placement: {len(result.ids)} jobs across "
          f"{len(MEMBER_SPECS)} clusters in {wall:.2f}s "
          f"({out['placement_jobs_per_s']:.0f} jobs/s)")
    print(f"  placed: {by_cluster} → {out['green_fraction']:.0%} on the "
          f"two lowest-carbon members")

    # -- 1b. urgent batch spreads by capacity (in-flight charging) ------------
    urgent_fed = make_federation()
    urgent_engine = SubmitEngine(urgent_fed, eco=False, coalesce=False)
    urgent = urgent_engine.submit_many(_jobs(200))
    spread: dict[str, int] = {}
    for jid in urgent.ids:
        spread[jid.split(":")[0]] = spread.get(jid.split(":")[0], 0) + 1
    out["urgent_spread"] = spread
    print(f"  urgent batch of 200 spreads across members: {spread}")

    # -- 2. conservation: nothing lost, nothing double-counted ----------------
    queue_ids = [r["jobid"] for r in fed.queue()]
    out["queued"] = len(queue_ids)
    out["queue_unique"] = len(set(queue_ids))
    fed_result = _collect_report(fed, "fed")
    rep = fed_result["report"]
    out["archived"] = fed_result["collected"]
    out["report_jobs"] = rep["total"]["jobs"]
    conserved = (
        out["queue_unique"] == len(result.ids)
        and out["archived"] == len(result.ids)
        and out["report_jobs"] == len(result.ids)
    )
    out["conserved"] = conserved
    print(f"  conservation: queue {out['queue_unique']}/{len(result.ids)} "
          f"unique, archive {out['archived']}, report {out['report_jobs']} "
          f"→ {'OK' if conserved else 'MISMATCH'}")

    # -- 3. carbon saved vs single-cluster baseline ---------------------------
    # same workload, everything forced onto the dirty default member
    baseline = make_federation()
    base_jobs = _jobs(1000)
    for j in base_jobs:
        j.cluster = MEMBER_SPECS[0][0]
    SubmitEngine(baseline, eco=True, coalesce=False, now=T0).submit_many(base_jobs)
    base_rep = _collect_report(baseline, "baseline")["report"]
    fed_carbon = rep["total"]["carbon_gco2"]
    base_carbon = base_rep["total"]["carbon_gco2"]
    out["carbon_gco2_federated"] = fed_carbon
    out["carbon_gco2_single_cluster"] = base_carbon
    out["carbon_saved_gco2"] = base_carbon - fed_carbon
    out["carbon_saved_pct"] = (
        100.0 * (base_carbon - fed_carbon) / base_carbon if base_carbon else 0.0
    )
    out["placement_saved_gco2_reported"] = rep["total"]["placement_saved_gco2"]
    print(f"  carbon: federated {fed_carbon:.0f} g vs single-cluster "
          f"{base_carbon:.0f} g → saved {out['carbon_saved_gco2']:.0f} g "
          f"({out['carbon_saved_pct']:.0f}%)")
    print(f"  report's own placement counterfactual: "
          f"{out['placement_saved_gco2_reported']:+.0f} g")
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
