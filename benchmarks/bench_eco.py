"""Eco-mode benchmark (paper §EcoScheduler + example commands).

Three measurements:
  1. the paper's exact example reproduces (2026-03-18 → 2026-03-19T00:00 T1);
  2. a year of simulated submissions: tier distribution, mean deferral, and
     peak-hour compute avoided vs the no-eco baseline (the paper's claimed
     benefit, quantified);
  3. scheduling decision latency (it sits on every submission path).
"""

from __future__ import annotations

import time
from datetime import datetime, timedelta

import numpy as np

from repro.core import CarbonTrace, EcoScheduler


def paper_example() -> dict:
    sched = EcoScheduler(
        weekday_windows=[(0, 360)], weekend_windows=[(0, 420), (660, 960)],
        peak_hours=[(1020, 1200)], horizon_days=14, min_delay_s=0,
    )
    d = sched.next_window(6 * 3600, datetime(2026, 3, 18, 10, 0))
    ok = d.begin_directive == "2026-03-19T00:00:00" and d.tier == 1
    return {"begin": d.begin_directive, "tier": d.tier, "matches_paper": ok}


def year_of_submissions(n: int = 2000, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    sched = EcoScheduler(
        weekday_windows=[(0, 360)], weekend_windows=[(0, 420), (660, 960)],
        peak_hours=[(1020, 1200)], horizon_days=14, min_delay_s=0,
    )
    start = datetime(2026, 1, 1)
    tiers = {0: 0, 1: 0, 2: 0, 3: 0}
    defer_h = []
    peak_hours_no_eco = 0.0
    peak_hours_eco = 0.0

    def peak_overlap_h(t0: datetime, dur_s: int) -> float:
        end = t0 + timedelta(seconds=dur_s)
        tot = 0.0
        for ps, pe in sched._absolute_peak_windows(t0, end):
            lo, hi = max(ps, t0), min(pe, end)
            if hi > lo:
                tot += (hi - lo).total_seconds() / 3600
        return tot

    for _ in range(n):
        # submissions during working hours, durations log-uniform 0.5-48 h
        t = start + timedelta(
            days=int(rng.integers(0, 365)),
            hours=int(rng.integers(8, 18)),
            minutes=int(rng.integers(0, 60)),
        )
        dur = int(3600 * float(np.exp(rng.uniform(np.log(0.5), np.log(48)))))
        d = sched.next_window(dur, t)
        tiers[d.tier] += 1
        defer_h.append((d.begin - t).total_seconds() / 3600)
        peak_hours_no_eco += peak_overlap_h(t, dur)
        peak_hours_eco += peak_overlap_h(d.begin, dur)

    return {
        "n": n,
        "tier_counts": tiers,
        "mean_deferral_h": float(np.mean(defer_h)),
        "p95_deferral_h": float(np.percentile(defer_h, 95)),
        "peak_core_hours_no_eco": round(peak_hours_no_eco, 1),
        "peak_core_hours_eco": round(peak_hours_eco, 1),
        "peak_compute_avoided": 1 - peak_hours_eco / max(peak_hours_no_eco, 1e-9),
    }


def decision_latency(n: int = 500) -> dict:
    sched = EcoScheduler(
        weekday_windows=[(0, 360)], weekend_windows=[(0, 420), (660, 960)],
        peak_hours=[(1020, 1200)], horizon_days=14, min_delay_s=0,
    )
    now = datetime(2026, 3, 18, 10, 0)
    t0 = time.perf_counter()
    for i in range(n):
        sched.next_window(3600 * (1 + i % 47), now + timedelta(hours=i))
    dt = (time.perf_counter() - t0) / n
    return {"mean_decision_ms": dt * 1e3}


def window_ablation(n: int = 600) -> list[dict]:
    """Ablation: how the eco benefit responds to the window budget.

    Sweeps the weekday-night window width (the institution's main knob) and
    reports tier-1 rate, mean deferral, and peak compute avoided — the
    trade-off curve an HPC operator would use to pick a policy."""
    rng = np.random.default_rng(7)
    submissions = []
    start = datetime(2026, 1, 1)
    for _ in range(n):
        t = start + timedelta(days=int(rng.integers(0, 365)),
                              hours=int(rng.integers(8, 18)),
                              minutes=int(rng.integers(0, 60)))
        dur = int(3600 * float(np.exp(rng.uniform(np.log(0.5), np.log(48)))))
        submissions.append((t, dur))

    out = []
    for hours in (2, 4, 6, 8, 12):
        sched = EcoScheduler(
            weekday_windows=[(0, hours * 60)],
            weekend_windows=[(0, 420), (660, 960)],
            peak_hours=[(1020, 1200)], horizon_days=14, min_delay_s=0,
        )
        tiers = {0: 0, 1: 0, 2: 0, 3: 0}
        defer = []
        for t, dur in submissions:
            d = sched.next_window(dur, t)
            tiers[d.tier] += 1
            defer.append((d.begin - t).total_seconds() / 3600)
        out.append({
            "weekday_window_h": hours,
            "tier1_rate": tiers[1] / n,
            "tier3_rate": tiers[3] / n,
            "mean_deferral_h": float(np.mean(defer)),
        })
    return out


def run() -> dict:
    out = {
        "paper_example": paper_example(),
        "year_sim": year_of_submissions(),
        "latency": decision_latency(),
        "window_ablation": window_ablation(),
    }
    ys = out["year_sim"]
    print(f"  paper example: begin={out['paper_example']['begin']} "
          f"tier={out['paper_example']['tier']} "
          f"matches_paper={out['paper_example']['matches_paper']}")
    print(f"  {ys['n']} submissions/yr: tiers={ys['tier_counts']} "
          f"mean_defer={ys['mean_deferral_h']:.1f}h")
    print(f"  peak-hour compute: {ys['peak_core_hours_no_eco']}h → "
          f"{ys['peak_core_hours_eco']}h "
          f"({ys['peak_compute_avoided']:.1%} avoided)")
    print(f"  decision latency: {out['latency']['mean_decision_ms']:.2f} ms")
    print("  window ablation (weekday night width → tier1 / tier3 / defer):")
    for rec in out["window_ablation"]:
        print(f"    {rec['weekday_window_h']:2d}h → {rec['tier1_rate']:.0%} / "
              f"{rec['tier3_rate']:.0%} / {rec['mean_deferral_h']:.1f}h")
    return out
