"""Perf-iteration driver (§Perf): lower one cell with config overrides,
report the three roofline terms + per-op attribution, and append the
iteration to results/perf/<arch>__<shape>.jsonl.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch mistral-large-123b \
        --shape train_4k --set seq_shard=True --set remat=selective \
        --tag seqpar+selremat

Each run is one hypothesis→change→measure cycle; the EXPERIMENTS.md §Perf
log is written from these artifacts.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402

from repro.analysis.hlo import analyze_hlo  # noqa: E402
from repro.analysis.roofline import roofline_report  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.launch.dryrun import SHAPES  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.optim import make_optimizer  # noqa: E402
from repro.parallel.sharding import resolve_tree, rules_for  # noqa: E402
from repro.training.steps import (  # noqa: E402
    abstract_train_state, make_prefill_step, make_serve_step, make_train_step,
    train_state_logical,
)

RESULTS = Path(__file__).resolve().parent.parent / "results" / "perf"


def parse_override(s: str):
    key, _, val = s.partition("=")
    for cast in (int, float):
        try:
            return key, cast(val)
        except ValueError:
            pass
    if val in ("True", "False"):
        return key, val == "True"
    return key, val


def lower_with_overrides(arch, shape, overrides, multi_pod=False):
    kind, seq, batch = SHAPES[shape]
    cfg = get_config(arch).replace(**overrides)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(
        cfg, mesh, param_defs=model.param_defs, batch_size=batch,
        extra_dims={"kv_seq": seq, "heads": cfg.n_heads, "seq": seq},
        fsdp=cfg.fsdp and kind == "train",
    )
    t0 = time.time()
    if kind == "train":
        optimizer = make_optimizer(cfg.optimizer)
        state = abstract_train_state(model, optimizer)
        state_sh = resolve_tree(mesh, train_state_logical(model, optimizer), rules)
        batch_sh = resolve_tree(mesh, model.train_input_logical(), rules)
        step = make_train_step(model, optimizer, rules, mesh)
        with mesh:
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                              out_shardings=(state_sh, None),
                              donate_argnums=(0,)).lower(
                state, model.train_inputs(batch, seq))
    elif kind == "prefill":
        params = model.abstract_params()
        params_sh = resolve_tree(mesh, model.param_logical(), rules)
        batch_sh = resolve_tree(mesh, model.prefill_input_logical(), rules)
        step = make_prefill_step(model, rules, mesh)
        with mesh:
            lowered = jax.jit(step, in_shardings=(params_sh, batch_sh)).lower(
                params, model.prefill_inputs(batch, seq))
    else:
        params = model.abstract_params()
        params_sh = resolve_tree(mesh, model.param_logical(), rules)
        cache = model.cache_defs_fn(batch, seq)
        cache_sh = resolve_tree(mesh, model.cache_logical_fn(), rules)
        toks = model.decode_inputs(batch)
        step = make_serve_step(model, rules, mesh)
        with mesh:
            lowered = jax.jit(step, in_shardings=(params_sh, cache_sh, None, None),
                              out_shardings=(None, cache_sh),
                              donate_argnums=(1,)).lower(
                params, cache, toks["tokens"], toks["pos"])
    compiled = lowered.compile()
    qb = min(cfg.attn_chunk, seq)
    st = analyze_hlo(compiled.as_text(), tile_dims=(qb, cfg.attn_chunk))
    kindmul = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[kind]
    tokens = batch if kind == "decode" else batch * seq
    model_flops = kindmul * cfg.active_param_count() * tokens
    rep = roofline_report(
        per_device_flops=st.flops,
        per_device_hbm_bytes=st.hbm_bytes,
        per_device_wire_bytes=st.collective_wire_bytes,
        chips=mesh.devices.size,
        model_flops=model_flops,
        tokens=tokens,
    )
    rep["compile_s"] = round(time.time() - t0, 1)
    # Pallas-path projection: flash kernel keeps score tiles in VMEM
    rep["attn_tile_bytes"] = st.attn_tile_bytes
    rep["memory_s_pallas"] = (st.hbm_bytes - st.attn_tile_bytes) / 819e9
    rep["step_lb_pallas_s"] = max(
        rep["compute_s"], rep["memory_s_pallas"], rep["collective_s"]
    )
    rep["mfu_pallas"] = (
        model_flops / (rep["step_lb_pallas_s"] * mesh.devices.size * 197e12)
        if rep["step_lb_pallas_s"] > 0 else 0.0
    )
    rep["top_bytes"] = st.top_bytes(8)
    rep["collective_by_type"] = st.collective_by_type
    rep["rules"] = {k: (list(v) if isinstance(v, tuple) else v)
                    for k, v in rules.items()}
    return rep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--set", action="append", default=[], dest="sets",
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--multi", action="store_true")
    args = ap.parse_args(argv)

    overrides = dict(parse_override(s) for s in args.sets)
    rep = lower_with_overrides(args.arch, args.shape, overrides, args.multi)
    rep.update(arch=args.arch, shape=args.shape, tag=args.tag,
               overrides=overrides)
    RESULTS.mkdir(parents=True, exist_ok=True)
    log = RESULTS / f"{args.arch.replace('-', '_')}__{args.shape}.jsonl"
    with log.open("a") as fh:
        fh.write(json.dumps(rep, default=str) + "\n")

    print(f"\n[{args.tag}] {args.arch} × {args.shape} "
          f"(overrides: {overrides or 'none'})")
    print(f"  compute    {rep['compute_s']:9.3f} s")
    print(f"  memory     {rep['memory_s']:9.3f} s   "
          f"(pallas-path: {rep['memory_s_pallas']:.3f} s — tiles in VMEM)")
    print(f"  collective {rep['collective_s']:9.3f} s")
    print(f"  mfu pallas-path {rep['mfu_pallas']:.2%}")
    print(f"  bottleneck {rep['bottleneck']}   roofline fraction "
          f"{rep['roofline_fraction_mfu']:.2%}   useful-FLOP ratio "
          f"{rep['useful_flop_ratio']:.2f}")
    print(f"  collectives: " + ", ".join(
        f"{k}={v:.2e}" for k, v in rep["collective_by_type"].items()))
    print("  top HBM traffic:")
    for op, b in rep["top_bytes"]:
        print(f"    {op:22s} {b:.3e}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
